//! Declarative command-line grammar.
//!
//! The old `main.rs` matched flag strings in a hand-rolled loop and each
//! subcommand re-parsed its own positionals; the serve protocol would have
//! needed a third copy. This module replaces all of that with two const
//! registries — [`FLAGS`] and [`COMMANDS`] — that are the single source of
//! truth for parsing ([`parse_cli`]), for `--help` ([`usage`] renders the
//! text from the registries, so help can never drift from the parser), and
//! for the serve wire protocol (each [`CommandSpec`] names the flags valid
//! on the wire; [`crate::server::request::Request::parse_line`] enforces
//! them).
//!
//! Semantics are unchanged from the hand-rolled loop: flags are recognized
//! anywhere on the line, unknown `-`-prefixed tokens are a hard error that
//! names the flag, value flags consume the next token, and everything else
//! is a positional. The service-shaped subcommands (`query`, `tune`,
//! `pareto`) lower into the typed [`Request`] the server also consumes, via
//! [`Cli::to_request`].

use crate::cluster::BackendKind;
use crate::config::ClusterConfig;
use crate::faults::SiteClass;
use crate::kernels::{Benchmark, Variant};
use crate::server::request::{QueryTier, Request, Selector};
use crate::transfp::FpMode;
use crate::tuner::{Probe, DEFAULT_BUDGET};

/// Parsed command line: recognized flags plus positional arguments.
/// Unknown flags are an error — a typo like `--cvs` must fail loudly, not
/// be silently treated as a positional (or worse, filtered away).
#[derive(Default)]
pub struct Cli {
    pub csv: bool,
    pub no_cache: bool,
    pub acc: bool,
    pub budget: Option<f64>,
    pub tiles: Option<usize>,
    pub backend: Option<BackendKind>,
    pub probe: Option<Probe>,
    /// `query`: execution tier for cache misses (default cycle-accurate).
    pub tier: Option<QueryTier>,
    pub jobs: Option<usize>,
    pub seed: Option<u64>,
    pub rate: Option<usize>,
    pub sites: Option<Vec<SiteClass>>,
    pub no_recover: bool,
    /// `serve`: TCP port to listen on (default [`DEFAULT_PORT`]).
    pub port: Option<u16>,
    /// `serve --stdin`: serve the stdin/stdout pipe instead of TCP.
    pub stdin_mode: bool,
    /// `serve`: write the per-endpoint metrics CSV here on exit.
    pub metrics: Option<String>,
    /// `trace`: ladder rung to trace (default scalar).
    pub variant: Option<Variant>,
    /// `trace`: print the per-core breakdown of one region.
    pub region: Option<String>,
    /// `trace`: export path override.
    pub out: Option<String>,
    /// `trace`: export format (default CSV).
    pub format: Option<TraceFormat>,
    pub args: Vec<String>,
}

/// Exporter format of the `trace` subcommand.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceFormat {
    /// Flat per-record CSV (`records_csv`).
    #[default]
    Csv,
    /// Chrome trace-event JSON (chrome://tracing, Perfetto).
    Chrome,
}

impl TraceFormat {
    /// Parse the `--format` value.
    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s {
            "csv" => Some(TraceFormat::Csv),
            "chrome" => Some(TraceFormat::Chrome),
            _ => None,
        }
    }

    /// File extension of the exported artifact.
    pub fn ext(self) -> &'static str {
        match self {
            TraceFormat::Csv => "csv",
            TraceFormat::Chrome => "json",
        }
    }
}

/// Default TCP port of `transpfp serve`.
pub const DEFAULT_PORT: u16 = 4517;

/// One entry of the flag registry.
pub struct FlagSpec {
    /// The flag itself, e.g. `--budget`.
    pub name: &'static str,
    /// Value placeholder for help (`<rel-err>`), or `None` for booleans.
    pub value: Option<&'static str>,
    /// Example value quoted in the missing-value error.
    pub example: &'static str,
    /// Help text; extra lines continue the help column.
    pub help: &'static str,
    /// Parse-and-store: receives the value token for value flags.
    apply: fn(&mut Cli, Option<&str>) -> Result<(), String>,
}

fn apply_csv(c: &mut Cli, _: Option<&str>) -> Result<(), String> {
    c.csv = true;
    Ok(())
}

fn apply_no_cache(c: &mut Cli, _: Option<&str>) -> Result<(), String> {
    c.no_cache = true;
    Ok(())
}

fn apply_acc(c: &mut Cli, _: Option<&str>) -> Result<(), String> {
    c.acc = true;
    Ok(())
}

fn apply_budget(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match v.parse::<f64>() {
        Ok(b) if b.is_finite() && b >= 0.0 => {
            c.budget = Some(b);
            Ok(())
        }
        _ => Err(format!("bad `--budget` value `{v}`")),
    }
}

fn apply_tiles(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match v.parse::<usize>() {
        Ok(t) if t >= 1 => {
            c.tiles = Some(t);
            Ok(())
        }
        _ => Err(format!("bad `--tiles` value `{v}`")),
    }
}

fn apply_backend(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match BackendKind::parse(v) {
        Some(b) => {
            c.backend = Some(b);
            Ok(())
        }
        None => Err(format!("bad `--backend` value `{v}`")),
    }
}

fn apply_probe(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match Probe::parse(v) {
        Some(p) => {
            c.probe = Some(p);
            Ok(())
        }
        None => Err(format!("bad `--probe` value `{v}`")),
    }
}

fn apply_tier(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match QueryTier::parse(v) {
        Some(t) => {
            c.tier = Some(t);
            Ok(())
        }
        None => Err(format!("bad `--tier` value `{v}` (cycle, functional or interpreter)")),
    }
}

fn apply_jobs(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => {
            c.jobs = Some(n);
            Ok(())
        }
        _ => Err(format!("bad `--jobs` value `{v}` (must be >= 1)")),
    }
}

fn apply_seed(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match v.parse::<u64>() {
        Ok(s) => {
            c.seed = Some(s);
            Ok(())
        }
        _ => Err(format!("bad `--seed` value `{v}`")),
    }
}

fn apply_rate(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => {
            c.rate = Some(n);
            Ok(())
        }
        _ => Err(format!("bad `--rate` value `{v}` (must be >= 1)")),
    }
}

fn apply_sites(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match SiteClass::parse_list(v) {
        Some(s) => {
            c.sites = Some(s);
            Ok(())
        }
        None => Err(format!("bad `--sites` value `{v}`")),
    }
}

fn apply_no_recover(c: &mut Cli, _: Option<&str>) -> Result<(), String> {
    c.no_recover = true;
    Ok(())
}

fn apply_port(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match v.parse::<u16>() {
        Ok(p) if p >= 1 => {
            c.port = Some(p);
            Ok(())
        }
        _ => Err(format!("bad `--port` value `{v}`")),
    }
}

fn apply_stdin(c: &mut Cli, _: Option<&str>) -> Result<(), String> {
    c.stdin_mode = true;
    Ok(())
}

fn apply_metrics(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    c.metrics = Some(v.expect("value flag").to_string());
    Ok(())
}

fn apply_variant(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match parse_variant(v) {
        Some(var) => {
            c.variant = Some(var);
            Ok(())
        }
        None => Err(format!("bad `--variant` value `{v}`")),
    }
}

fn apply_region(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    c.region = Some(v.expect("value flag").to_string());
    Ok(())
}

fn apply_out(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    c.out = Some(v.expect("value flag").to_string());
    Ok(())
}

fn apply_format(c: &mut Cli, v: Option<&str>) -> Result<(), String> {
    let v = v.expect("value flag");
    match TraceFormat::parse(v) {
        Some(f) => {
            c.format = Some(f);
            Ok(())
        }
        None => Err(format!("bad `--format` value `{v}` (csv or chrome)")),
    }
}

/// Every flag the binary understands, in help order.
pub const FLAGS: &[FlagSpec] = &[
    FlagSpec {
        name: "--csv",
        value: None,
        example: "",
        help: "CSV output for table/fig/pareto/query/tune/inject",
        apply: apply_csv,
    },
    FlagSpec {
        name: "--no-cache",
        value: None,
        example: "",
        help: "don't load or persist the measurement cache",
        apply: apply_no_cache,
    },
    FlagSpec {
        name: "--acc",
        value: None,
        example: "",
        help: "accuracy-extended frontier (pareto only)",
        apply: apply_acc,
    },
    FlagSpec {
        name: "--budget",
        value: Some("<rel-err>"),
        example: "1e-2",
        help: "error budget for `tune` and `inject` (default 1e-2)",
        apply: apply_budget,
    },
    FlagSpec {
        name: "--tiles",
        value: Some("<t>"),
        example: "8",
        help: "run the DMA double-buffered tiled kernel with t\ntiles (`run` with MATMUL or CONV, scalar)",
        apply: apply_tiles,
    },
    FlagSpec {
        name: "--backend",
        value: Some("<b>"),
        example: "functional",
        help: "execution tier for `run`: event, reference,\nfunctional or compiled (architectural-only,\nno timing)",
        apply: apply_backend,
    },
    FlagSpec {
        name: "--probe",
        value: Some("<p>"),
        example: "compiled",
        help: "accuracy probe for `tune`: compiled (default),\nfunctional or cycle",
        apply: apply_probe,
    },
    FlagSpec {
        name: "--tier",
        value: Some("<t>"),
        example: "functional",
        help: "execution tier for `query` misses: cycle\n(default, real timing), functional (compiled\narchitectural fast path, no timing) or\ninterpreter (functional interpreter opt-out)",
        apply: apply_tier,
    },
    FlagSpec {
        name: "--jobs",
        value: Some("<n>"),
        example: "4",
        help: "cap sweep/query worker threads (default: all\ncores, at most 16)",
        apply: apply_jobs,
    },
    FlagSpec {
        name: "--seed",
        value: Some("<s>"),
        example: "7",
        help: "campaign sampling seed for `inject` (default 1)",
        apply: apply_seed,
    },
    FlagSpec {
        name: "--rate",
        value: Some("<n>"),
        example: "16",
        help: "injected points per benchmark x rung for `inject`\n(default 8)",
        apply: apply_rate,
    },
    FlagSpec {
        name: "--sites",
        value: Some("<list>"),
        example: "tcdm,reg,dma",
        help: "structure classes for `inject`: comma-separated\nsubset of tcdm,reg,dma, or `all` (default all)",
        apply: apply_sites,
    },
    FlagSpec {
        name: "--no-recover",
        value: None,
        example: "",
        help: "disable the detect-and-retry recovery loop for\n`inject` (report raw outcomes only)",
        apply: apply_no_recover,
    },
    FlagSpec {
        name: "--port",
        value: Some("<n>"),
        example: "4517",
        help: "TCP port for `serve` (default 4517, loopback only)",
        apply: apply_port,
    },
    FlagSpec {
        name: "--stdin",
        value: None,
        example: "",
        help: "`serve` over the stdin/stdout pipe instead of TCP\n(replies on stdout, summary on stderr)",
        apply: apply_stdin,
    },
    FlagSpec {
        name: "--metrics",
        value: Some("<path>"),
        example: "metrics.csv",
        help: "write the per-endpoint serve metrics CSV here on\nexit (`serve --stdin` only)",
        apply: apply_metrics,
    },
    FlagSpec {
        name: "--variant",
        value: Some("<v>"),
        example: "vector",
        help: "ladder rung for `trace` (default scalar)",
        apply: apply_variant,
    },
    FlagSpec {
        name: "--region",
        value: Some("<name>"),
        example: "tile0",
        help: "also print the per-core breakdown of one trace\nregion (`trace` only)",
        apply: apply_region,
    },
    FlagSpec {
        name: "--out",
        value: Some("<path>"),
        example: "trace.json",
        help: "trace export path (default\nartifacts/trace/<kernel>.<csv|json>)",
        apply: apply_out,
    },
    FlagSpec {
        name: "--format",
        value: Some("<f>"),
        example: "chrome",
        help: "trace export format: csv (flat records, default)\nor chrome (trace-event JSON for chrome://tracing\nand Perfetto)",
        apply: apply_format,
    },
];

/// One entry of the command registry (drives `--help` and the wire-protocol
/// flag allowlists; dispatch stays in `main.rs`).
pub struct CommandSpec {
    pub name: &'static str,
    /// Positional grammar shown in help, e.g. `<cfg> <bench> <variant>`.
    pub args: &'static str,
    /// Help text; extra lines continue the help column.
    pub help: &'static str,
    /// Flags valid for this command **on the serve wire** (the CLI is
    /// permissive and accepts any registered flag anywhere; the wire is
    /// strict so a malformed request fails structurally, not silently).
    pub wire_flags: &'static [&'static str],
    /// Whether the command is servable over the wire at all.
    pub wire: bool,
}

/// Every subcommand, in help order.
pub const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "configs",
        args: "",
        help: "list the Table 2 design space",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "run",
        args: "<cfg> <bench> <variant>",
        help: "run one benchmark (e.g. `run 8c4f1p MATMUL vector`);\nvariants: scalar, scalar-f16, scalar-bf16,\nvector (vector-f16), vector-bf16; with\n--tiles <t>, run the DMA double-buffered tiled\nbuild (MATMUL/CONV scalar, dataset in L2 beyond\nthe TCDM, streamed through ping-pong buffers);\nwith --backend\n<event|reference|functional|compiled>, run\nuncached on the chosen execution tier (the\nfunctional and compiled tiers verify numerics\nwith no timing; compiled pre-translates the\nprogram into fused blocks)",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "trace",
        args: "<cfg> <bench>",
        help: "cycle-attribution trace of one benchmark run:\nrecords per-core issue/stall/wait/DMA events,\nprints the region attribution table (stall\ntaxonomy + DMA-overlap efficiency, reconciled\nexactly against the run's counters) and exports\nthe trace with --format csv|chrome to --out\n(default artifacts/trace/). --variant picks the\nladder rung (default scalar), --tiles traces the\nDMA double-buffered build, --region adds one\nregion's per-core breakdown. On the serve wire,\n`trace` (no args) lists recent request spans",
        wire_flags: &[],
        wire: true,
    },
    CommandSpec {
        name: "query",
        args: "<cfg|all> <bench|all> <variant|all>",
        help: "resolve a batch of design-space points through the\nmeasurement cache (plan stats on stderr); `all`\nspans the full 5-rung precision ladder; --tier\nfunctional resolves misses architecturally on\nthe compiled fast path (no timing)",
        wire_flags: &["--tier"],
        wire: true,
    },
    CommandSpec {
        name: "tune",
        args: "[cfg|all]",
        help: "accuracy-aware precision autotuning: select the\ncheapest admissible ladder rung per benchmark\nunder --budget (relative L2 error vs the f64\nreference; default 1e-2); default config 8c8f1p.\n--probe compiled (default) measures every rung's\naccuracy on the translated compiled tier and\nsimulates only admissible rungs; --probe\nfunctional probes on the interpreter (same\naccuracy, slower); --probe cycle restores\nall-cycle-accurate probing",
        wire_flags: &["--budget", "--probe"],
        wire: true,
    },
    CommandSpec {
        name: "pareto",
        args: "",
        help: "Pareto frontier of the full design space over\n(Gflop/s, Gflop/s/W, Gflop/s/mm^2); with --acc,\nthe accuracy-extended frontier over\n(rel. error, Gflop/s, Gflop/s/W) across the ladder",
        wire_flags: &["--acc"],
        wire: true,
    },
    CommandSpec {
        name: "table3",
        args: "",
        help: "FP/memory intensities (measured vs paper)",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "table4",
        args: "",
        help: "8-core benchmark tables (perf / e-eff / a-eff)",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "table5",
        args: "",
        help: "16-core benchmark tables",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "table6",
        args: "",
        help: "state-of-the-art comparison (measured + paper)",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "fig3",
        args: "",
        help: "fmax spread per pipeline/corner",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "fig4",
        args: "",
        help: "area per configuration",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "fig5",
        args: "",
        help: "power @100 MHz per configuration (cache-backed)",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "fig6",
        args: "",
        help: "parallel + vectorization speed-ups on the 16-core\nconfigurations: occupancy (1..=16 workers) is\nswept through the fork-join runtime's teams and\nresolved via the measurement cache",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "fig7",
        args: "",
        help: "metrics vs FPU sharing factor",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "fig8",
        args: "",
        help: "metrics vs pipeline stages",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "validate",
        args: "[dir]",
        help: "check simulator numerics vs XLA goldens (artifacts/)",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "sweep",
        args: "",
        help: "run the full 18x8x2 design space, CSV to stdout",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "inject",
        args: "<cfg>",
        help: "seeded SEU fault-injection campaign on one config:\nsamples --rate upset points per benchmark x rung\nfrom the --seed stream, flips one bit per run in a\n--sites structure (TCDM word, register cell, or\nin-flight DMA payload), and classifies every point\nas masked / tolerable / sdc / crash / hang against\nthe fault-free baseline and the binary64 reference\n(--budget splits tolerable from sdc). Summary table\nby default; --csv emits the per-point campaign CSV.\nDeterministic: same seed + flags => bit-identical\nCSV, regardless of --jobs",
        wire_flags: &[],
        wire: false,
    },
    CommandSpec {
        name: "serve",
        args: "",
        help: "long-running query service: newline-delimited\nquery/tune/pareto/inject-status/stats/ping\nrequests on TCP 127.0.0.1:--port (or the stdin\npipe with --stdin), framed `ok <n>`/`err <class>`\nreplies, single-flight dedup of identical\nin-flight requests, per-endpoint metrics; see\nEXPERIMENTS.md \u{a7}Serve for the protocol grammar",
        wire_flags: &[],
        wire: false,
    },
    // Wire-only endpoints (no CLI dispatch; sent to a running `serve`).
    CommandSpec {
        name: "inject-status",
        args: "",
        help: "(wire only) structured failure-class counters\nobserved by the service since start",
        wire_flags: &[],
        wire: true,
    },
    CommandSpec {
        name: "stats",
        args: "",
        help: "(wire only) engine + cache + request counters",
        wire_flags: &[],
        wire: true,
    },
    CommandSpec {
        name: "ping",
        args: "",
        help: "(wire only) liveness check, replies `pong`",
        wire_flags: &[],
        wire: true,
    },
];

/// Look a command up in the registry.
pub fn command_spec(name: &str) -> Option<&'static CommandSpec> {
    COMMANDS.iter().find(|c| c.name == name)
}

/// Comma-separated summary of every flag (for the unknown-flag error).
fn flag_summary() -> String {
    let mut s = String::new();
    for (i, f) in FLAGS.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(f.name);
        if let Some(v) = f.value {
            s.push(' ');
            s.push_str(v);
        }
    }
    s
}

/// Parse a raw argument list against the flag registry. Flags may appear
/// anywhere; value flags consume the next token; unknown `-`-prefixed
/// tokens fail with an error naming the flag and listing the registry.
pub fn parse_cli<I: IntoIterator<Item = String>>(raw: I) -> Result<Cli, String> {
    let mut cli = Cli::default();
    let mut it = raw.into_iter();
    while let Some(a) = it.next() {
        if let Some(spec) = FLAGS.iter().find(|f| f.name == a) {
            let value = if spec.value.is_some() {
                Some(it.next().ok_or_else(|| {
                    format!(
                        "flag `{}` needs a value (e.g. `{} {}`)",
                        spec.name, spec.name, spec.example
                    )
                })?)
            } else {
                None
            };
            (spec.apply)(&mut cli, value.as_deref())?;
        } else if a.starts_with('-') {
            return Err(format!("unknown flag `{a}` (known flags: {})", flag_summary()));
        } else {
            cli.args.push(a);
        }
    }
    Ok(cli)
}

/// Variant names accepted by `run` and `query`: the canonical labels
/// (single source of truth: [`Variant::parse_label`]) plus historical
/// short-form aliases.
pub fn parse_variant(s: &str) -> Option<Variant> {
    Variant::parse_label(s).or_else(|| match s {
        "sf16" => Some(Variant::SCALAR_F16),
        "sbf16" => Some(Variant::SCALAR_BF16),
        "vector" | "f16" => Some(Variant::VEC),
        "bf16" => Some(Variant::Vector(FpMode::VecBf16)),
        _ => None,
    })
}

fn parse_cfg_selector(s: &str) -> Result<Selector<ClusterConfig>, String> {
    if s == "all" {
        return Ok(Selector::All);
    }
    ClusterConfig::parse(s)
        .map(Selector::One)
        .ok_or_else(|| format!("bad config mnemonic {s}"))
}

fn parse_bench_selector(s: &str) -> Result<Selector<Benchmark>, String> {
    if s == "all" {
        return Ok(Selector::All);
    }
    Benchmark::parse(s).map(Selector::One).ok_or_else(|| format!("unknown benchmark {s}"))
}

fn parse_variant_selector(s: &str) -> Result<Selector<Variant>, String> {
    if s == "all" {
        return Ok(Selector::All);
    }
    parse_variant(s).map(Selector::One).ok_or_else(|| format!("unknown variant {s}"))
}

impl Cli {
    /// Lower the service-shaped subcommands into the typed [`Request`] the
    /// server consumes — the CLI `query`/`tune`/`pareto` paths and the wire
    /// protocol build identical values through this one function.
    pub fn to_request(&self) -> Result<Request, String> {
        let args: Vec<&str> = self.args.iter().map(|s| s.as_str()).collect();
        let Some(&cmd) = args.first() else {
            return Err("empty request".to_string());
        };
        match cmd {
            "query" => {
                if args.len() != 4 {
                    return Err("usage: query <cfg|all> <bench|all> <variant|all>".to_string());
                }
                Ok(Request::Query {
                    cfg: parse_cfg_selector(args[1])?,
                    bench: parse_bench_selector(args[2])?,
                    variant: parse_variant_selector(args[3])?,
                    tier: self.tier.unwrap_or_default(),
                })
            }
            "tune" => {
                if args.len() > 2 {
                    return Err(
                        "usage: tune [cfg|all] [--budget <rel-err>] [--probe <p>]".to_string()
                    );
                }
                let cfg = match args.get(1) {
                    None => Selector::One(ClusterConfig::new(8, 8, 1)),
                    Some(&s) => parse_cfg_selector(s)?,
                };
                Ok(Request::Tune {
                    cfg,
                    budget: self.budget.unwrap_or(DEFAULT_BUDGET),
                    probe: self.probe.unwrap_or(Probe::Compiled),
                })
            }
            "pareto" => {
                if args.len() != 1 {
                    return Err("usage: pareto [--acc]".to_string());
                }
                Ok(Request::Pareto { acc: self.acc })
            }
            "inject-status" => Ok(Request::InjectStatus),
            "stats" => Ok(Request::Stats),
            "trace" => {
                if args.len() != 1 {
                    // The CLI `trace <cfg> <bench>` form dispatches in
                    // main.rs; the service form lists recent request spans
                    // and takes no arguments.
                    return Err("`trace` takes no arguments on the wire".to_string());
                }
                Ok(Request::Trace)
            }
            "ping" => Ok(Request::Ping),
            other => Err(format!(
                "`{other}` is not a service request (expected query, tune, pareto, \
                 inject-status, stats, trace or ping)"
            )),
        }
    }
}

/// Append a `head` / multi-line `help` entry in the two-column help layout.
fn render_entry(out: &mut String, head: &str, help: &str) {
    let mut lines = help.lines();
    let first = lines.next().unwrap_or("");
    if head.len() <= 22 {
        out.push_str(&format!("  {head:<22}  {first}\n"));
    } else {
        out.push_str(&format!("  {head}\n"));
        out.push_str(&format!("  {:<22}  {first}\n", ""));
    }
    for l in lines {
        out.push_str(&format!("  {:<22}  {l}\n", ""));
    }
}

/// The full `--help` text, rendered from [`COMMANDS`] and [`FLAGS`]. Help
/// is *generated*, not hand-maintained: a flag or command that exists in
/// the registry is documented, one that doesn't isn't.
pub fn usage() -> String {
    let mut out = String::with_capacity(4096);
    out.push_str("transpfp — transprecision FP cluster reproduction (TPDS 2021)\n\n");
    out.push_str("USAGE: transpfp <command> [args] [flags]\n\nCOMMANDS:\n");
    for c in COMMANDS {
        let head =
            if c.args.is_empty() { c.name.to_string() } else { format!("{} {}", c.name, c.args) };
        render_entry(&mut out, &head, c.help);
    }
    out.push_str("\nFLAGS:\n");
    for f in FLAGS {
        let head = match f.value {
            Some(v) => format!("{} {v}", f.name),
            None => f.name.to_string(),
        };
        render_entry(&mut out, &head, f.help);
    }
    out.push_str(
        "\nSimulation failures are structured, never panics: a hung or deadlocked run\n\
         is reported with its watchdog class, failing query points are listed per\n\
         point (resolved points stay cached), and the exit code is non-zero.\n\
         \n\
         Measurements are memoized under artifacts/cache/measurements.csv, keyed by\n\
         (program fingerprint, config, variant, occupancy, fidelity, engine\n\
         version); see EXPERIMENTS.md §Cache + §Tuner + §Backends + §Serve for the\n\
         invalidation rules. TRANSPFP_CACHE_DIR overrides the directory.",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner;

    fn cli(args: &[&str]) -> Result<Cli, String> {
        parse_cli(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn known_flags_are_extracted_in_any_position() {
        let c = cli(&["table4", "--csv"]).unwrap();
        assert!(c.csv && !c.no_cache);
        assert_eq!(c.args, vec!["table4"]);

        let c = cli(&["--no-cache", "query", "all", "FIR", "--csv", "scalar"]).unwrap();
        assert!(c.csv && c.no_cache);
        assert_eq!(c.args, vec!["query", "all", "FIR", "scalar"]);
    }

    #[test]
    fn unknown_flags_are_rejected_not_filtered() {
        for bad in ["--cvs", "--cache", "-x", "--", "--csv=always", "--budget=1e-2"] {
            let err = cli(&["table4", bad]).unwrap_err();
            assert!(
                err.contains(bad.split('=').next().unwrap()),
                "error must name the flag: {err}"
            );
        }
        // Positionals are never mistaken for flags.
        assert!(cli(&["run", "8c4f1p", "MATMUL", "vector"]).is_ok());
    }

    #[test]
    fn budget_flag_takes_a_value() {
        let c = cli(&["tune", "--budget", "1e-3", "--csv"]).unwrap();
        assert_eq!(c.budget, Some(1e-3));
        assert!(c.csv);
        assert_eq!(c.args, vec!["tune"]);

        assert!(cli(&["tune", "--budget"]).is_err(), "missing value must fail");
        assert!(cli(&["tune", "--budget", "not-a-number"]).is_err());
        assert!(cli(&["tune", "--budget", "-1"]).is_err(), "negative budget is invalid");
        assert!(cli(&["tune", "--budget", "inf"]).is_err(), "non-finite budget is invalid");

        let c = cli(&["pareto", "--acc"]).unwrap();
        assert!(c.acc && c.budget.is_none());
    }

    #[test]
    fn backend_probe_and_jobs_flags_take_values() {
        let c = cli(&["run", "8c4f1p", "FIR", "scalar", "--backend", "functional"]).unwrap();
        assert_eq!(c.backend, Some(BackendKind::Functional));
        assert_eq!(c.args, vec!["run", "8c4f1p", "FIR", "scalar"]);
        let r = cli(&["run", "--backend", "ref"]).unwrap();
        assert_eq!(r.backend, Some(BackendKind::Reference));
        let co = cli(&["run", "--backend", "compiled"]).unwrap();
        assert_eq!(co.backend, Some(BackendKind::Compiled));
        assert!(cli(&["run", "--backend"]).is_err(), "missing value must fail");
        assert!(cli(&["run", "--backend", "turbo"]).is_err());

        let c = cli(&["tune", "--probe", "functional"]).unwrap();
        assert_eq!(c.probe, Some(tuner::Probe::Functional));
        let q = cli(&["tune", "--probe", "compiled"]).unwrap();
        assert_eq!(q.probe, Some(tuner::Probe::Compiled));
        let p = cli(&["tune", "--probe", "cycle"]).unwrap();
        assert_eq!(p.probe, Some(tuner::Probe::CycleAccurate));
        assert!(cli(&["tune", "--probe"]).is_err());
        assert!(cli(&["tune", "--probe", "psychic"]).is_err());

        let c = cli(&["sweep", "--jobs", "4"]).unwrap();
        assert_eq!(c.jobs, Some(4));
        assert!(cli(&["sweep", "--jobs"]).is_err(), "missing value must fail");
        assert!(cli(&["sweep", "--jobs", "0"]).is_err(), "zero workers is invalid");
        assert!(cli(&["sweep", "--jobs", "many"]).is_err());
    }

    #[test]
    fn tier_flag_takes_a_value() {
        let c = cli(&["query", "8c8f1p", "FIR", "scalar", "--tier", "functional"]).unwrap();
        assert_eq!(c.tier, Some(QueryTier::Functional));
        assert_eq!(c.args, vec!["query", "8c8f1p", "FIR", "scalar"]);
        let c = cli(&["query", "all", "all", "all", "--tier", "cycle"]).unwrap();
        assert_eq!(c.tier, Some(QueryTier::Cycle));
        let c = cli(&["query", "all", "all", "all", "--tier", "interpreter"]).unwrap();
        assert_eq!(c.tier, Some(QueryTier::Interpreter));
        assert!(cli(&["query", "--tier"]).is_err(), "missing value must fail");
        assert!(cli(&["query", "--tier", "quantum"]).is_err());
    }

    #[test]
    fn tiles_flag_takes_a_value() {
        let c = cli(&["run", "8c8f1p", "MATMUL", "scalar", "--tiles", "8"]).unwrap();
        assert_eq!(c.tiles, Some(8));
        assert_eq!(c.args, vec!["run", "8c8f1p", "MATMUL", "scalar"]);
        assert!(cli(&["run", "--tiles"]).is_err(), "missing value must fail");
        assert!(cli(&["run", "--tiles", "0"]).is_err(), "zero tiles is invalid");
        assert!(cli(&["run", "--tiles", "x"]).is_err());
    }

    #[test]
    fn inject_flags_take_values() {
        let c = cli(&["inject", "8c8f1p", "--seed", "7", "--rate", "16"]).unwrap();
        assert_eq!(c.seed, Some(7));
        assert_eq!(c.rate, Some(16));
        assert_eq!(c.args, vec!["inject", "8c8f1p"]);
        assert!(!c.no_recover && c.sites.is_none());

        let c = cli(&["inject", "8c8f1p", "--sites", "tcdm,dma", "--no-recover"]).unwrap();
        assert_eq!(c.sites, Some(vec![SiteClass::Tcdm, SiteClass::Dma]));
        assert!(c.no_recover);
        let c = cli(&["inject", "8c8f1p", "--sites", "all"]).unwrap();
        assert_eq!(c.sites, Some(SiteClass::all().to_vec()));

        assert!(cli(&["inject", "--seed"]).is_err(), "missing value must fail");
        assert!(cli(&["inject", "--seed", "x"]).is_err());
        assert!(cli(&["inject", "--rate", "0"]).is_err(), "zero points is invalid");
        assert!(cli(&["inject", "--sites", "l2"]).is_err(), "unknown site class");
        assert!(cli(&["inject", "--sites"]).is_err());
    }

    #[test]
    fn variant_names() {
        assert_eq!(parse_variant("scalar"), Some(Variant::Scalar));
        assert_eq!(parse_variant("scalar-f16"), Some(Variant::SCALAR_F16));
        assert_eq!(parse_variant("sbf16"), Some(Variant::SCALAR_BF16));
        assert_eq!(parse_variant("vector"), Some(Variant::VEC));
        assert_eq!(parse_variant("vector-f16"), Some(Variant::VEC));
        assert_eq!(parse_variant("f16"), Some(Variant::VEC));
        assert_eq!(parse_variant("bf16"), Some(Variant::Vector(FpMode::VecBf16)));
        assert_eq!(parse_variant("vector-bf16"), Some(Variant::Vector(FpMode::VecBf16)));
        assert_eq!(parse_variant("f64"), None);
        // Every canonical label parses.
        for v in Variant::all() {
            assert_eq!(parse_variant(v.label()), Some(v));
        }
    }

    #[test]
    fn serve_flags_parse() {
        let c = cli(&["serve", "--port", "9000"]).unwrap();
        assert_eq!(c.port, Some(9000));
        assert!(!c.stdin_mode);
        let c = cli(&["serve", "--stdin", "--metrics", "m.csv"]).unwrap();
        assert!(c.stdin_mode);
        assert_eq!(c.metrics.as_deref(), Some("m.csv"));
        assert!(cli(&["serve", "--port"]).is_err(), "missing value must fail");
        assert!(cli(&["serve", "--port", "0"]).is_err(), "port 0 is invalid");
        assert!(cli(&["serve", "--port", "70000"]).is_err(), "out-of-range port is invalid");
    }

    #[test]
    fn usage_is_generated_from_the_registries() {
        let u = usage();
        for c in COMMANDS {
            assert!(u.contains(c.name), "help must document command {}", c.name);
        }
        for f in FLAGS {
            assert!(u.contains(f.name), "help must document flag {}", f.name);
        }
        // The serve protocol pointer survives rendering.
        assert!(u.contains("§Serve"));
    }

    #[test]
    fn to_request_lowers_service_commands() {
        let c = cli(&["query", "8c8f1p", "FIR", "scalar"]).unwrap();
        let r = c.to_request().unwrap();
        assert_eq!(
            r,
            Request::Query {
                cfg: Selector::One(ClusterConfig::new(8, 8, 1)),
                bench: Selector::One(Benchmark::Fir),
                variant: Selector::One(Variant::Scalar),
                tier: QueryTier::Cycle,
            }
        );
        let c = cli(&["query", "8c8f1p", "FIR", "scalar", "--tier", "functional"]).unwrap();
        match c.to_request().unwrap() {
            Request::Query { tier, .. } => assert_eq!(tier, QueryTier::Functional),
            other => panic!("expected Query, got {other:?}"),
        }

        let c = cli(&["tune"]).unwrap();
        match c.to_request().unwrap() {
            Request::Tune { cfg, budget, probe } => {
                assert_eq!(cfg, Selector::One(ClusterConfig::new(8, 8, 1)));
                assert_eq!(budget, DEFAULT_BUDGET);
                assert_eq!(probe, Probe::Compiled, "tune defaults to the compiled probe");
            }
            other => panic!("expected Tune, got {other:?}"),
        }

        let c = cli(&["pareto", "--acc"]).unwrap();
        assert_eq!(c.to_request().unwrap(), Request::Pareto { acc: true });

        assert!(cli(&["query", "bad", "FIR", "scalar"]).unwrap().to_request().is_err());
        assert!(cli(&["query", "8c8f1p"]).unwrap().to_request().is_err());
        assert!(cli(&["run", "8c8f1p", "FIR", "scalar"]).unwrap().to_request().is_err());
    }
}
