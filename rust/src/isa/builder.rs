//! Program builder — the "assembler" the benchmark kernels are written
//! against. Provides labels with backpatching, hardware-loop scoping, and
//! mnemonic-style helpers so kernels read like the Xpulp assembly the
//! paper's toolchain emits.

use std::collections::HashMap;

use super::insn::{AluOp, AmoOp, BrCond, FpOp, Insn, MemSize, Operand, Reg};
use crate::transfp::{CmpPred, FpMode};

/// Convention registers (mirrors the HAL of §4: core id / ncores live in
/// known registers after startup).
pub mod regs {
    use super::Reg;
    /// Hardwired zero.
    pub const ZERO: Reg = 0;
    /// Core id, written by the simulator at reset.
    pub const CORE_ID: Reg = 10;
    /// Number of cores in the cluster, written at reset.
    pub const NCORES: Reg = 11;
    /// First caller-scratch register conventionally used by kernels.
    pub const T0: Reg = 12;
}

/// A trace-region marker attached to an instruction index. Markers are
/// metadata only: they are not instructions, cost no cycles, and are
/// invisible to `DecodedProgram` (and to its fingerprint). The tracer
/// fires a pc's markers when the instruction at that pc issues on a core.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MarkerOp {
    /// Enter the named attribution region.
    Enter(String),
    /// Leave the region opened by the statically matching `Enter`. A core
    /// that never entered it (the exit pc may be shared with a path that
    /// branched over the region) ignores the fire.
    Exit,
}

/// A finished SPMD program: every core executes the same instruction stream.
#[derive(Debug, Clone)]
pub struct Program {
    /// Resolved instruction stream.
    pub insns: Vec<Insn>,
    /// Human-readable name (benchmark + variant).
    pub name: String,
    /// Trace-region markers, `(instruction index, op)` in emission order.
    pub markers: Vec<(u32, MarkerOp)>,
}

impl Program {
    /// Static instruction count.
    pub fn len(&self) -> usize {
        self.insns.len()
    }

    /// True if the program has no instructions.
    pub fn is_empty(&self) -> bool {
        self.insns.is_empty()
    }
}

/// Label-resolving program builder.
pub struct ProgramBuilder {
    insns: Vec<Insn>,
    labels: HashMap<String, u32>,
    /// (instruction index, label) pairs to backpatch.
    fixups: Vec<(usize, String)>,
    /// Open hardware loops: (index of HwLoop insn, body start).
    hwloop_stack: Vec<usize>,
    name: String,
    markers: Vec<(u32, MarkerOp)>,
    /// Open `region_enter` calls, for balance checking at build time.
    region_stack: Vec<String>,
}

impl ProgramBuilder {
    /// Start a new program.
    pub fn new(name: impl Into<String>) -> Self {
        ProgramBuilder {
            insns: Vec::new(),
            labels: HashMap::new(),
            fixups: Vec::new(),
            hwloop_stack: Vec::new(),
            name: name.into(),
            markers: Vec::new(),
            region_stack: Vec::new(),
        }
    }

    /// Next instruction index.
    pub fn here(&self) -> u32 {
        self.insns.len() as u32
    }

    /// Define `label` at the current position.
    pub fn label(&mut self, label: &str) -> &mut Self {
        let prev = self.labels.insert(label.to_string(), self.here());
        assert!(prev.is_none(), "duplicate label {label}");
        self
    }

    fn push(&mut self, i: Insn) -> &mut Self {
        self.insns.push(i);
        self
    }

    // ---------------------------------------------------------- integer

    /// `li rd, imm`
    pub fn li(&mut self, rd: Reg, imm: u32) -> &mut Self {
        self.push(Insn::Li { rd, imm })
    }

    /// `add rd, rs1, rs2`
    pub fn add(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Add, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    /// `addi rd, rs1, imm`
    pub fn addi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Add, rd, rs1, rhs: Operand::Imm(imm) })
    }

    /// `sub rd, rs1, rs2`
    pub fn sub(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Sub, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    /// `mul rd, rs1, rs2` (single-cycle on RI5CY)
    pub fn mul(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Mul, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    /// `muli rd, rs1, imm` (strength-reduced by the compiler; modelled 1 cycle)
    pub fn muli(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Mul, rd, rs1, rhs: Operand::Imm(imm) })
    }

    /// `div rd, rs1, rs2` — iterative integer divide.
    pub fn divi(&mut self, rd: Reg, rs1: Reg, rhs: Operand) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Div, rd, rs1, rhs })
    }

    /// `rem rd, rs1, rs2`
    pub fn rem(&mut self, rd: Reg, rs1: Reg, rhs: Operand) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Rem, rd, rs1, rhs })
    }

    /// `slli rd, rs1, imm`
    pub fn slli(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Sll, rd, rs1, rhs: Operand::Imm(imm) })
    }

    /// `srli rd, rs1, imm`
    pub fn srli(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Srl, rd, rs1, rhs: Operand::Imm(imm) })
    }

    /// `andi rd, rs1, imm`
    pub fn andi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::And, rd, rs1, rhs: Operand::Imm(imm) })
    }

    /// `xor rd, rs1, rs2`
    pub fn xor(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Xor, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    /// `or rd, rs1, rs2`
    pub fn or(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Or, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    /// `mv rd, rs` (addi rd, rs, 0)
    pub fn mv(&mut self, rd: Reg, rs: Reg) -> &mut Self {
        self.addi(rd, rs, 0)
    }

    /// Xpulp `p.min rd, rs1, rs2`
    pub fn imin(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Min, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    /// Xpulp `p.max rd, rs1, rs2`
    pub fn imax(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Max, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    /// `slt rd, rs1, rs2`
    pub fn slt(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Alu { op: AluOp::Slt, rd, rs1, rhs: Operand::Reg(rs2) })
    }

    // ---------------------------------------------------------- memory

    /// `lw rd, offset(base)`
    pub fn lw(&mut self, rd: Reg, base: Reg, offset: i32) -> &mut Self {
        self.push(Insn::Load { rd, base, offset, post_inc: 0, size: MemSize::Word })
    }

    /// Xpulp post-increment load word: `p.lw rd, inc(base!)`
    pub fn lw_pi(&mut self, rd: Reg, base: Reg, inc: i32) -> &mut Self {
        self.push(Insn::Load { rd, base, offset: 0, post_inc: inc, size: MemSize::Word })
    }

    /// `lh rd, offset(base)` (sign-extending halfword load)
    pub fn lh(&mut self, rd: Reg, base: Reg, offset: i32) -> &mut Self {
        self.push(Insn::Load { rd, base, offset, post_inc: 0, size: MemSize::Half })
    }

    /// `lhu rd, offset(base)` (zero-extending halfword load — the natural
    /// load for 16-bit FP bit patterns, which live in lane 0).
    pub fn lhu(&mut self, rd: Reg, base: Reg, offset: i32) -> &mut Self {
        self.push(Insn::Load { rd, base, offset, post_inc: 0, size: MemSize::HalfU })
    }

    /// Xpulp post-increment zero-extending halfword load: `p.lhu rd, inc(base!)`
    pub fn lhu_pi(&mut self, rd: Reg, base: Reg, inc: i32) -> &mut Self {
        self.push(Insn::Load { rd, base, offset: 0, post_inc: inc, size: MemSize::HalfU })
    }

    /// `sw rs, offset(base)`
    pub fn sw(&mut self, rs: Reg, base: Reg, offset: i32) -> &mut Self {
        self.push(Insn::Store { rs, base, offset, post_inc: 0, size: MemSize::Word })
    }

    /// Xpulp post-increment store word.
    pub fn sw_pi(&mut self, rs: Reg, base: Reg, inc: i32) -> &mut Self {
        self.push(Insn::Store { rs, base, offset: 0, post_inc: inc, size: MemSize::Word })
    }

    /// `sh rs, offset(base)`
    pub fn sh(&mut self, rs: Reg, base: Reg, offset: i32) -> &mut Self {
        self.push(Insn::Store { rs, base, offset, post_inc: 0, size: MemSize::Half })
    }

    /// Xpulp post-increment halfword store.
    pub fn sh_pi(&mut self, rs: Reg, base: Reg, inc: i32) -> &mut Self {
        self.push(Insn::Store { rs, base, offset: 0, post_inc: inc, size: MemSize::Half })
    }

    // ---------------------------------------------------------- control

    /// Conditional branch to `label`.
    pub fn br(&mut self, cond: BrCond, rs1: Reg, rs2: Reg, label: &str) -> &mut Self {
        self.fixups.push((self.insns.len(), label.to_string()));
        self.push(Insn::Branch { cond, rs1, rs2, target: u32::MAX })
    }

    /// `bne rs1, rs2, label`
    pub fn bne(&mut self, rs1: Reg, rs2: Reg, label: &str) -> &mut Self {
        self.br(BrCond::Ne, rs1, rs2, label)
    }

    /// `beq rs1, rs2, label`
    pub fn beq(&mut self, rs1: Reg, rs2: Reg, label: &str) -> &mut Self {
        self.br(BrCond::Eq, rs1, rs2, label)
    }

    /// `blt rs1, rs2, label`
    pub fn blt(&mut self, rs1: Reg, rs2: Reg, label: &str) -> &mut Self {
        self.br(BrCond::Lt, rs1, rs2, label)
    }

    /// `bge rs1, rs2, label`
    pub fn bge(&mut self, rs1: Reg, rs2: Reg, label: &str) -> &mut Self {
        self.br(BrCond::Ge, rs1, rs2, label)
    }

    /// Unconditional jump to `label`.
    pub fn j(&mut self, label: &str) -> &mut Self {
        self.fixups.push((self.insns.len(), label.to_string()));
        self.push(Insn::Jump { target: u32::MAX })
    }

    /// Open a zero-overhead hardware loop executing its body `count`
    /// (register) times. Must be closed with [`Self::hwloop_end`]. Nesting
    /// depth ≤2 like RI5CY.
    pub fn hwloop(&mut self, count: Reg) -> &mut Self {
        assert!(self.hwloop_stack.len() < 2, "RI5CY supports 2 nested hw loops");
        self.hwloop_stack.push(self.insns.len());
        self.push(Insn::HwLoop { count, start: 0, end: 0 })
    }

    /// Close the innermost hardware loop.
    pub fn hwloop_end(&mut self) -> &mut Self {
        let idx = self.hwloop_stack.pop().expect("hwloop_end without hwloop");
        let start = idx as u32 + 1;
        let end = self.here();
        assert!(end > start, "empty hardware loop body");
        if let Insn::HwLoop { start: s, end: e, .. } = &mut self.insns[idx] {
            *s = start;
            *e = end;
        }
        self
    }

    /// Atomic fetch-and-add on a TCDM word: `rd = mem[base+offset]`,
    /// `mem[base+offset] += rs` — the work-sharing scheduler's chunk grab.
    pub fn amo_add(&mut self, rd: Reg, base: Reg, offset: i32, rs: Reg) -> &mut Self {
        self.push(Insn::Amo { op: AmoOp::Add, rd, base, offset, rs })
    }

    /// Atomic swap on a TCDM word: `rd = mem[base+offset]`,
    /// `mem[base+offset] = rs` — test-and-set locks.
    pub fn amo_swap(&mut self, rd: Reg, base: Reg, offset: i32, rs: Reg) -> &mut Self {
        self.push(Insn::Amo { op: AmoOp::Swap, rd, base, offset, rs })
    }

    /// Event-unit synchronization barrier.
    pub fn barrier(&mut self) -> &mut Self {
        self.push(Insn::Barrier)
    }

    /// Sleep until software event line `ev` is raised (consumes a buffered
    /// event without sleeping).
    pub fn wait_event(&mut self, ev: u8) -> &mut Self {
        assert!((ev as usize) < crate::cluster::event::NUM_EVENTS, "event line out of range");
        self.push(Insn::WaitEvent { ev })
    }

    /// Raise software event line `ev` for every core.
    pub fn set_event(&mut self, ev: u8) -> &mut Self {
        assert!((ev as usize) < crate::cluster::event::NUM_EVENTS, "event line out of range");
        self.push(Insn::SetEvent { ev })
    }

    /// Terminate the core.
    pub fn end(&mut self) -> &mut Self {
        self.push(Insn::End)
    }

    // ---------------------------------------------------------- trace regions

    /// Open a named trace-attribution region at the *next* instruction:
    /// the region begins when that instruction issues. Free — markers are
    /// metadata, not instructions. Must be closed with
    /// [`Self::region_exit`] on the same control path; regions nest.
    pub fn region_enter(&mut self, name: &str) -> &mut Self {
        self.region_stack.push(name.to_string());
        self.markers.push((self.here(), MarkerOp::Enter(name.to_string())));
        self
    }

    /// Close the innermost open region at the *next* instruction: cycles up
    /// to (but not including) that instruction's issue stay attributed to
    /// the region.
    pub fn region_exit(&mut self) -> &mut Self {
        assert!(self.region_stack.pop().is_some(), "region_exit without region_enter");
        self.markers.push((self.here(), MarkerOp::Exit));
        self
    }

    // ---------------------------------------------------------- FP

    /// Generic FP op.
    pub fn fp(&mut self, op: FpOp, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Insn::Fp { op, mode, rd, rs1, rs2 })
    }

    /// `fadd` / `vfadd` in `mode`.
    pub fn fadd(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Add, mode, rd, rs1, rs2)
    }

    /// `fsub` / `vfsub`.
    pub fn fsub(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Sub, mode, rd, rs1, rs2)
    }

    /// `fmul` / `vfmul`.
    pub fn fmul(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Mul, mode, rd, rs1, rs2)
    }

    /// `fmac rd, rs1, rs2` — `rd += rs1*rs2` (scalar or per-lane).
    pub fn fmac(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Mac, mode, rd, rs1, rs2)
    }

    /// Widening 16→32 FMA (`fmac.s.h` style).
    pub fn fmac_widen(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::MacWiden, mode, rd, rs1, rs2)
    }

    /// Expanding SIMD dot product (`vfdotpex.s.X`).
    pub fn fdotp(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::DotpWiden, mode, rd, rs1, rs2)
    }

    /// `fdiv` — shared DIV-SQRT block.
    pub fn fdiv(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Div, mode, rd, rs1, rs2)
    }

    /// `fsqrt`.
    pub fn fsqrt(&mut self, mode: FpMode, rd: Reg, rs1: Reg) -> &mut Self {
        self.fp(FpOp::Sqrt, mode, rd, rs1, 0)
    }

    /// `fmin`.
    pub fn fmin(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Min, mode, rd, rs1, rs2)
    }

    /// `fmax`.
    pub fn fmax(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Max, mode, rd, rs1, rs2)
    }

    /// FP compare writing 0/1 (scalar) or masks (vector).
    pub fn fcmp(&mut self, mode: FpMode, pred: CmpPred, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Cmp(pred), mode, rd, rs1, rs2)
    }

    /// `fneg rd, rs`.
    pub fn fneg(&mut self, mode: FpMode, rd: Reg, rs1: Reg) -> &mut Self {
        self.fp(FpOp::Neg, mode, rd, rs1, 0)
    }

    /// `fabs rd, rs`.
    pub fn fabs(&mut self, mode: FpMode, rd: Reg, rs1: Reg) -> &mut Self {
        self.fp(FpOp::AbsF, mode, rd, rs1, 0)
    }

    /// `fcvt.X.w rd, rs` — int to float.
    pub fn fcvt_from_int(&mut self, mode: FpMode, rd: Reg, rs1: Reg) -> &mut Self {
        self.fp(FpOp::FromInt, mode, rd, rs1, 0)
    }

    /// `fcvt.w.X rd, rs` — float to int (RTZ).
    pub fn fcvt_to_int(&mut self, mode: FpMode, rd: Reg, rs1: Reg) -> &mut Self {
        self.fp(FpOp::ToInt, mode, rd, rs1, 0)
    }

    /// `fcvt.h.s`-style narrow (mode names the 16-bit target).
    pub fn fcvt_down(&mut self, mode: FpMode, rd: Reg, rs1: Reg) -> &mut Self {
        self.fp(FpOp::CvtDown, mode, rd, rs1, 0)
    }

    /// `fcvt.s.h`-style widen (mode names the 16-bit source).
    pub fn fcvt_up(&mut self, mode: FpMode, rd: Reg, rs1: Reg) -> &mut Self {
        self.fp(FpOp::CvtUp, mode, rd, rs1, 0)
    }

    /// Cast-and-pack two f32 scalars into a 2×16 vector (`vfcpka.X.s`).
    pub fn cpka(&mut self, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::Cpka, mode, rd, rs1, rs2)
    }

    /// SIMD shuffle with immediate selector in `rs2` slot.
    pub fn vshuffle(&mut self, rd: Reg, rs1: Reg, sel: u8) -> &mut Self {
        self.fp(FpOp::Shuffle, FpMode::VecF16, rd, rs1, sel)
    }

    /// Pack low lanes of two vectors.
    pub fn vpack_lo(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::PackLo, FpMode::VecF16, rd, rs1, rs2)
    }

    /// Pack high lanes of two vectors.
    pub fn vpack_hi(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.fp(FpOp::PackHi, FpMode::VecF16, rd, rs1, rs2)
    }

    // ---------------------------------------------------------- finish

    /// Resolve labels and produce the program.
    pub fn build(mut self) -> Program {
        assert!(self.hwloop_stack.is_empty(), "unclosed hardware loop");
        assert!(
            self.region_stack.is_empty(),
            "unclosed trace regions: {:?}",
            self.region_stack
        );
        for (idx, label) in std::mem::take(&mut self.fixups) {
            let target = *self
                .labels
                .get(&label)
                .unwrap_or_else(|| panic!("undefined label {label}"));
            match &mut self.insns[idx] {
                Insn::Branch { target: t, .. } | Insn::Jump { target: t } => *t = target,
                other => panic!("fixup on non-branch {other:?}"),
            }
        }
        // Safety net: every program must end.
        if !matches!(self.insns.last(), Some(Insn::End)) {
            self.insns.push(Insn::End);
        }
        // Every marker must sit on a real instruction (a `region_exit`
        // right before the auto-appended `End` lands on the `End` itself).
        let len = self.insns.len() as u32;
        for (pc, op) in &self.markers {
            assert!(*pc < len, "marker {op:?} at pc {pc} past program end {len}");
        }
        Program { insns: self.insns, name: self.name, markers: self.markers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_resolve() {
        let mut b = ProgramBuilder::new("t");
        b.li(1, 4);
        b.label("loop");
        b.addi(1, 1, -1);
        b.bne(1, 0, "loop");
        b.end();
        let p = b.build();
        match p.insns[2] {
            Insn::Branch { target, .. } => assert_eq!(target, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn hwloop_backpatches_bounds() {
        let mut b = ProgramBuilder::new("t");
        b.li(1, 8);
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.addi(3, 3, 2);
        b.hwloop_end();
        b.end();
        let p = b.build();
        match p.insns[1] {
            Insn::HwLoop { start, end, .. } => {
                assert_eq!(start, 2);
                assert_eq!(end, 4);
            }
            _ => panic!(),
        }
    }

    #[test]
    #[should_panic(expected = "undefined label")]
    fn undefined_label_panics() {
        let mut b = ProgramBuilder::new("t");
        b.j("nowhere");
        b.build();
    }

    #[test]
    fn end_appended_if_missing() {
        let mut b = ProgramBuilder::new("t");
        b.li(1, 1);
        let p = b.build();
        assert!(matches!(p.insns.last(), Some(Insn::End)));
    }

    #[test]
    fn region_markers_attach_to_next_insn() {
        let mut b = ProgramBuilder::new("t");
        b.li(1, 1);
        b.region_enter("hot");
        b.addi(1, 1, 1);
        b.addi(1, 1, 2);
        b.region_exit();
        // Exit marker lands on the auto-appended End.
        let p = b.build();
        assert_eq!(p.markers.len(), 2);
        assert_eq!(p.markers[0], (1, MarkerOp::Enter("hot".to_string())));
        assert_eq!(p.markers[1], (3, MarkerOp::Exit));
        assert!(matches!(p.insns[3], Insn::End));
    }

    #[test]
    #[should_panic(expected = "unclosed trace regions")]
    fn unbalanced_region_panics() {
        let mut b = ProgramBuilder::new("t");
        b.region_enter("dangling");
        b.li(1, 1);
        b.build();
    }

    #[test]
    #[should_panic(expected = "region_exit without region_enter")]
    fn exit_without_enter_panics() {
        let mut b = ProgramBuilder::new("t");
        b.li(1, 1);
        b.region_exit();
    }
}
