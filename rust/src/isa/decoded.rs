//! Predecoded programs for the simulator hot path.
//!
//! [`DecodedProgram::decode`] lowers a [`Program`] into a dense array of
//! [`DecodedInsn`]s: the scoreboard read set, the static issue class, the
//! write-back / FP / locality flags and the fixed issue latency are all
//! resolved once per program instead of being re-derived from the `Insn`
//! enum on every issue (the per-issue pattern matching and predicate calls
//! were the single largest line item in the simulator profile — see
//! EXPERIMENTS.md §Perf).
//!
//! The decode is pure metadata: the architectural payload stays in the
//! embedded [`Insn`], so the functional semantics have exactly one
//! implementation shared by both issue engines.

use super::builder::Program;
use super::insn::{AluOp, FpOp, Insn, Operand, Reg};

/// Latency of the iterative integer divider (RI5CY serial divider).
pub const INT_DIV_LATENCY: u64 = 35;
/// Taken-branch penalty (total cycles occupied by the branch).
pub const TAKEN_BRANCH_CYCLES: u64 = 3;

/// Static issue class: which structural-hazard path an instruction takes.
/// Collapses the chain of `matches!` predicates the issue loop used to
/// evaluate per instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    /// 1-cycle (or iterative-divide) integer ALU op.
    Alu,
    /// Load immediate.
    Li,
    /// Memory load (region resolved at run time).
    Load,
    /// Memory store.
    Store,
    /// Conditional branch.
    Branch,
    /// Unconditional jump.
    Jump,
    /// Hardware-loop setup.
    HwLoop,
    /// Integer-SIMD lane permutation (`pv.shuffle`/`pv.pack*`): executes on
    /// the core ALU, never touches the FPU.
    FpAlu,
    /// FP divide/sqrt on the shared iterative DIV-SQRT block.
    FpDivSqrt,
    /// FP op on the (possibly shared) FPU datapath.
    Fp,
    /// Atomic read-modify-write on a TCDM bank (scheduler work queues).
    Amo,
    /// Event-unit barrier.
    Barrier,
    /// Event-unit software-event sleep (`WaitEvent`).
    WaitEvent,
    /// Event-unit software-event raise (`SetEvent`).
    SetEvent,
    /// Core termination.
    End,
}

/// Static property flags of a decoded instruction.
pub mod flag {
    /// Touches no cross-core shared resource whose arbitration is order-
    /// sensitive: the event engine may execute it ahead of the global clock
    /// inside a batched straight-line run. (The shared I$ is handled
    /// separately — fills are order-insensitive within a cycle and batches
    /// stop at non-resident lines.)
    pub const LOCAL: u8 = 1 << 0;
    /// Writes an integer/FP destination register (write-back port model).
    pub const WRITES_REG: u8 = 1 << 1;
    /// Is an `Insn::Fp` (exempt from the §5.3.3 write-back conflict check).
    pub const FP: u8 = 1 << 2;
    /// Packed-SIMD FP op (counts toward `fp_vec_instrs`).
    pub const VEC: u8 = 1 << 3;
    /// `pc + 1` is the end of some hardware loop in the program: the
    /// sequential-advance path must consult the hw-loop stack. When clear,
    /// `pc += 1` is always correct and the stack walk is skipped.
    pub const LOOP_END_NEXT: u8 = 1 << 4;
}

/// One predecoded instruction. ~40 bytes, laid out for the issue loop:
/// everything the hazard checks need is in the header fields; the
/// architectural payload is the embedded [`Insn`].
#[derive(Debug, Clone, Copy)]
pub struct DecodedInsn {
    /// Dispatch class.
    pub class: OpClass,
    /// Scoreboard read set (resolved operand slots), in check order.
    pub reads: [Reg; 3],
    /// Number of valid entries in `reads`.
    pub nreads: u8,
    /// Static property flags (`flag::*`).
    pub flags: u8,
    /// Issue→reuse latency for the fixed-latency classes (`Alu`, `Li`,
    /// `FpAlu`): 1, or [`INT_DIV_LATENCY`] for the iterative divider.
    pub latency: u64,
    /// The architectural instruction (functional payload).
    pub insn: Insn,
}

impl DecodedInsn {
    /// Test a `flag::*` bit.
    #[inline(always)]
    pub fn has(&self, f: u8) -> bool {
        self.flags & f != 0
    }
}

/// A predecoded program: dense, index-addressed by the same pc as the
/// source [`Program`].
#[derive(Debug, Clone)]
pub struct DecodedProgram {
    /// Decoded instruction stream (same indices as `Program::insns`).
    pub insns: Vec<DecodedInsn>,
    /// Straight-line fast-path table: `local_run_len[pc]` is the number of
    /// consecutive instructions starting at `pc` that carry [`flag::LOCAL`]
    /// — i.e. the longest prefix that touches no order-sensitive shared
    /// resource. `0` means `pc` itself is a contention/synchronization
    /// point. Shared by the event engine's batcher (a non-zero entry is
    /// exactly the "may keep the issue slot" predicate) and the functional
    /// interpreter (a non-zero entry selects the core-local dispatch tier
    /// that never consults memory, the FPUs or the event unit).
    pub local_run_len: Vec<u32>,
}

impl DecodedProgram {
    /// Lower `program` into its predecoded form.
    pub fn decode(program: &Program) -> DecodedProgram {
        // Collect every hardware-loop end boundary so sequential advances
        // can skip the stack walk everywhere else.
        let mut loop_ends: Vec<u32> = program
            .insns
            .iter()
            .filter_map(|i| match i {
                Insn::HwLoop { end, .. } => Some(*end),
                _ => None,
            })
            .collect();
        loop_ends.sort_unstable();
        loop_ends.dedup();

        let insns = program
            .insns
            .iter()
            .enumerate()
            .map(|(idx, insn)| {
                let (reads, nreads) = insn.read_regs();
                let (class, latency, local) = classify(insn);
                let mut flags = 0u8;
                if local {
                    flags |= flag::LOCAL;
                }
                if insn.writes_int_reg() {
                    flags |= flag::WRITES_REG;
                }
                if insn.is_fp() {
                    flags |= flag::FP;
                }
                if let Insn::Fp { mode, .. } = insn {
                    if matches!(class, OpClass::Fp) && mode.is_vector() {
                        flags |= flag::VEC;
                    }
                }
                if loop_ends.binary_search(&(idx as u32 + 1)).is_ok() {
                    flags |= flag::LOOP_END_NEXT;
                }
                DecodedInsn { class, reads, nreads, flags, latency, insn: *insn }
            })
            .collect();
        DecodedProgram { local_run_len: run_lengths(&insns), insns }
    }

    /// Static instruction count.
    pub fn len(&self) -> usize {
        self.insns.len()
    }

    /// Hardware-loop body ranges as `(head, tail)` pc pairs, both
    /// inclusive: the body of `HwLoop { start, end, .. }` spans
    /// `start..end`, so its last instruction sits at `end - 1`. Degenerate
    /// (empty) bodies are dropped. This is the trace-formation seed for the
    /// compiled tier ([`crate::cluster::compiled`]): each candidate body is
    /// screened there for admissibility before becoming a loop trace.
    pub fn hw_loop_bodies(&self) -> Vec<(u32, u32)> {
        self.insns
            .iter()
            .filter_map(|d| match d.insn {
                Insn::HwLoop { start, end, .. } if end > start => Some((start, end - 1)),
                _ => None,
            })
            .collect()
    }

    /// True if the program has no instructions.
    pub fn is_empty(&self) -> bool {
        self.insns.is_empty()
    }

    /// Stable 64-bit content fingerprint of the predecoded instruction
    /// stream (FNV-1a over the decode metadata *and* the architectural
    /// payload of every instruction). Two programs fingerprint equal iff
    /// their instruction streams are identical; the measurement cache
    /// ([`crate::coordinator::cache`]) folds this with the staged data and
    /// goldens to content-address results, so editing a kernel invalidates
    /// exactly its own entries, and the compiled tier's code cache
    /// ([`crate::cluster::compiled`]) uses it alone as the translation key.
    /// The hash is independent of allocation addresses and run state —
    /// decoding the same [`Program`] twice, before or after
    /// `Cluster::reset()`, always reproduces it.
    ///
    /// The encoding is structural, not textual: every field is folded into
    /// the FNV stream as fixed-width bytes behind a per-variant tag, so the
    /// layout after each tag is self-delimiting and no separator characters
    /// exist to be confused by field contents. (An earlier version hashed
    /// `Debug` renderings joined with `;`/`/`, which was both ambiguous in
    /// principle and the slow path of every cache-key computation.)
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv1a::new();
        for d in &self.insns {
            h.byte(d.class as u8);
            h.byte(d.flags);
            h.u64(d.latency);
            fold_insn(&mut h, &d.insn);
        }
        h.0
    }
}

/// Fold one architectural instruction into the fingerprint stream: a
/// variant tag byte followed by that variant's fields in declaration
/// order, each at a fixed width (registers and fieldless enums as one
/// byte, immediates/targets as 4 little-endian bytes). Exhaustive over
/// [`Insn`] — adding a variant forces a tag choice here.
fn fold_insn(h: &mut Fnv1a, insn: &Insn) {
    match insn {
        Insn::Alu { op, rd, rs1, rhs } => {
            h.byte(0);
            h.byte(*op as u8);
            h.byte(*rd);
            h.byte(*rs1);
            fold_operand(h, rhs);
        }
        Insn::Li { rd, imm } => {
            h.byte(1);
            h.byte(*rd);
            h.u32(*imm);
        }
        Insn::Load { rd, base, offset, post_inc, size } => {
            h.byte(2);
            h.byte(*rd);
            h.byte(*base);
            h.u32(*offset as u32);
            h.u32(*post_inc as u32);
            h.byte(*size as u8);
        }
        Insn::Store { rs, base, offset, post_inc, size } => {
            h.byte(3);
            h.byte(*rs);
            h.byte(*base);
            h.u32(*offset as u32);
            h.u32(*post_inc as u32);
            h.byte(*size as u8);
        }
        Insn::Branch { cond, rs1, rs2, target } => {
            h.byte(4);
            h.byte(*cond as u8);
            h.byte(*rs1);
            h.byte(*rs2);
            h.u32(*target);
        }
        Insn::Jump { target } => {
            h.byte(5);
            h.u32(*target);
        }
        Insn::HwLoop { count, start, end } => {
            h.byte(6);
            h.byte(*count);
            h.u32(*start);
            h.u32(*end);
        }
        Insn::Fp { op, mode, rd, rs1, rs2 } => {
            h.byte(7);
            fold_fp_op(h, op);
            h.byte(*mode as u8);
            h.byte(*rd);
            h.byte(*rs1);
            h.byte(*rs2);
        }
        Insn::Amo { op, rd, base, offset, rs } => {
            h.byte(8);
            h.byte(*op as u8);
            h.byte(*rd);
            h.byte(*base);
            h.u32(*offset as u32);
            h.byte(*rs);
        }
        Insn::Barrier => h.byte(9),
        Insn::WaitEvent { ev } => {
            h.byte(10);
            h.byte(*ev);
        }
        Insn::SetEvent { ev } => {
            h.byte(11);
            h.byte(*ev);
        }
        Insn::End => h.byte(12),
    }
}

/// Tag byte per [`FpOp`] variant; `Cmp` carries its predicate as one extra
/// byte (fixed layout per tag keeps the stream self-delimiting).
fn fold_fp_op(h: &mut Fnv1a, op: &FpOp) {
    let tag: u8 = match op {
        FpOp::Add => 0,
        FpOp::Sub => 1,
        FpOp::Mul => 2,
        FpOp::Mac => 3,
        FpOp::MacWiden => 4,
        FpOp::DotpWiden => 5,
        FpOp::Min => 6,
        FpOp::Max => 7,
        FpOp::Cmp(_) => 8,
        FpOp::Div => 9,
        FpOp::Sqrt => 10,
        FpOp::Neg => 11,
        FpOp::AbsF => 12,
        FpOp::FromInt => 13,
        FpOp::ToInt => 14,
        FpOp::CvtDown => 15,
        FpOp::CvtUp => 16,
        FpOp::Cpka => 17,
        FpOp::Shuffle => 18,
        FpOp::PackLo => 19,
        FpOp::PackHi => 20,
    };
    h.byte(tag);
    if let FpOp::Cmp(p) = op {
        h.byte(*p as u8);
    }
}

/// Operand as a tag byte (register vs immediate) plus 4 value bytes — a
/// register and an immediate with the same bit pattern never collide.
fn fold_operand(h: &mut Fnv1a, rhs: &Operand) {
    match rhs {
        Operand::Reg(r) => {
            h.byte(0);
            h.u32(u32::from(*r));
        }
        Operand::Imm(i) => {
            h.byte(1);
            h.u32(*i as u32);
        }
    }
}

/// Backward scan computing the straight-line fast-path table: the run
/// length at `pc` is `0` for non-[`flag::LOCAL`] instructions and
/// `1 + run[pc + 1]` otherwise (the final instruction of a program is
/// always `End`, which is local, so the recurrence is well-founded).
fn run_lengths(insns: &[DecodedInsn]) -> Vec<u32> {
    let mut run = vec![0u32; insns.len()];
    let mut next = 0u32;
    for (pc, d) in insns.iter().enumerate().rev() {
        next = if d.flags & flag::LOCAL != 0 { next + 1 } else { 0 };
        run[pc] = next;
    }
    run
}

/// 64-bit FNV-1a accumulator used for the program fingerprint. Fields are
/// folded in as raw bytes (no intermediate formatting or allocation); the
/// empty stream hashes to the FNV-1a offset basis.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    #[inline(always)]
    fn byte(&mut self, b: u8) {
        self.0 = (self.0 ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
    }

    #[inline(always)]
    fn u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    #[inline(always)]
    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }
}

/// Class, fixed latency, and locality of an instruction.
fn classify(insn: &Insn) -> (OpClass, u64, bool) {
    match insn {
        Insn::Alu { op, .. } => {
            let lat = if matches!(op, AluOp::Div | AluOp::Rem) { INT_DIV_LATENCY } else { 1 };
            (OpClass::Alu, lat, true)
        }
        Insn::Li { .. } => (OpClass::Li, 1, true),
        Insn::Load { .. } => (OpClass::Load, 1, false),
        Insn::Store { .. } => (OpClass::Store, 1, false),
        Insn::Branch { .. } => (OpClass::Branch, 1, true),
        Insn::Jump { .. } => (OpClass::Jump, TAKEN_BRANCH_CYCLES, true),
        Insn::HwLoop { .. } => (OpClass::HwLoop, 1, true),
        Insn::Fp { op, .. } => {
            if op.is_alu_class() {
                (OpClass::FpAlu, 1, true)
            } else if op.is_divsqrt() {
                (OpClass::FpDivSqrt, 1, false)
            } else {
                (OpClass::Fp, 1, false)
            }
        }
        // Atomics touch a shared TCDM bank, and the event unit's wake/buffer
        // decisions depend on cross-core ordering within a cycle — all three
        // are contention points the event engine must execute at the global
        // clock, in rotation order.
        Insn::Amo { .. } => (OpClass::Amo, 1, false),
        Insn::Barrier => (OpClass::Barrier, 1, false),
        Insn::WaitEvent { .. } => (OpClass::WaitEvent, 1, false),
        Insn::SetEvent { .. } => (OpClass::SetEvent, 1, false),
        Insn::End => (OpClass::End, 1, true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::ProgramBuilder;
    use crate::transfp::FpMode;

    #[test]
    fn classes_and_latencies() {
        let mut b = ProgramBuilder::new("cls");
        b.li(1, 7); // 0: Li
        b.addi(2, 1, 1); // 1: Alu lat 1
        b.divi(3, 2, crate::isa::Operand::Reg(1)); // 2: Alu lat 35
        b.lw(4, 1, 0); // 3: Load
        b.fmac(FpMode::F32, 5, 4, 4); // 4: Fp
        b.fdiv(FpMode::F32, 6, 5, 5); // 5: FpDivSqrt
        b.vshuffle(7, 6, 0); // 6: FpAlu
        b.barrier(); // 7: Barrier
        b.end(); // 8: End
        let d = DecodedProgram::decode(&b.build());
        let cls: Vec<OpClass> = d.insns.iter().map(|i| i.class).collect();
        assert_eq!(
            cls,
            [
                OpClass::Li,
                OpClass::Alu,
                OpClass::Alu,
                OpClass::Load,
                OpClass::Fp,
                OpClass::FpDivSqrt,
                OpClass::FpAlu,
                OpClass::Barrier,
                OpClass::End
            ]
        );
        assert_eq!(d.insns[1].latency, 1);
        assert_eq!(d.insns[2].latency, INT_DIV_LATENCY);
        // Locality: int/permute ops batch; memory, FPU, barrier do not.
        assert!(d.insns[1].has(flag::LOCAL));
        assert!(d.insns[6].has(flag::LOCAL));
        assert!(!d.insns[3].has(flag::LOCAL));
        assert!(!d.insns[4].has(flag::LOCAL));
        assert!(!d.insns[7].has(flag::LOCAL));
        // FP flag exempts all Insn::Fp from the WB-conflict check.
        assert!(d.insns[4].has(flag::FP) && d.insns[5].has(flag::FP) && d.insns[6].has(flag::FP));
        assert!(!d.insns[3].has(flag::FP));
        // Read sets match the scoreboard's (FMA reads rs1, rs2, then rd).
        assert_eq!(&d.insns[4].reads[..d.insns[4].nreads as usize], &[4, 4, 5]);
    }

    #[test]
    fn runtime_ops_are_contention_points() {
        let mut b = ProgramBuilder::new("rt");
        b.amo_add(3, 4, 0, 5); // 0
        b.amo_swap(3, 4, 4, 5); // 1
        b.wait_event(2); // 2
        b.set_event(2); // 3
        b.end();
        let d = DecodedProgram::decode(&b.build());
        assert_eq!(d.insns[0].class, OpClass::Amo);
        assert_eq!(d.insns[1].class, OpClass::Amo);
        assert_eq!(d.insns[2].class, OpClass::WaitEvent);
        assert_eq!(d.insns[3].class, OpClass::SetEvent);
        for i in 0..4 {
            assert!(!d.insns[i].has(flag::LOCAL), "insn {i} must not batch");
        }
        // Atomics write rd like a load (WB-port model), events write nothing.
        assert!(d.insns[0].has(flag::WRITES_REG));
        assert!(!d.insns[2].has(flag::WRITES_REG));
        assert_eq!(&d.insns[0].reads[..d.insns[0].nreads as usize], &[5, 4]);
        assert_eq!(d.insns[2].nreads, 0);
    }

    #[test]
    fn loop_end_flags_mark_back_edges_only() {
        let mut b = ProgramBuilder::new("loops");
        b.li(1, 3); // 0
        b.hwloop(1); // 1 (body 2..4, end = 4)
        b.addi(2, 2, 1); // 2
        b.addi(3, 3, 1); // 3  ← pc+1 == 4 == loop end
        b.hwloop_end();
        b.li(4, 9); // 4
        b.end(); // 5
        let d = DecodedProgram::decode(&b.build());
        assert!(d.insns[3].has(flag::LOOP_END_NEXT));
        for i in [0usize, 1, 2, 4] {
            assert!(!d.insns[i].has(flag::LOOP_END_NEXT), "insn {i}");
        }
        assert_eq!(d.len(), 6);
        assert!(!d.is_empty());
    }

    #[test]
    fn hw_loop_bodies_are_inclusive_pc_ranges() {
        let mut b = ProgramBuilder::new("bodies");
        b.li(1, 3); // 0
        b.hwloop(1); // 1 (body 2..4 → head 2, tail 3)
        b.addi(2, 2, 1); // 2
        b.addi(3, 3, 1); // 3
        b.hwloop_end();
        b.end(); // 4
        let d = DecodedProgram::decode(&b.build());
        assert_eq!(d.hw_loop_bodies(), vec![(2, 3)]);

        // No hw loops → no bodies.
        let mut p = ProgramBuilder::new("plain");
        p.li(1, 1);
        p.end();
        assert!(DecodedProgram::decode(&p.build()).hw_loop_bodies().is_empty());
    }

    #[test]
    fn fingerprint_is_content_addressed() {
        let build = |imm: u32| {
            let mut b = ProgramBuilder::new("fp");
            b.li(1, imm);
            b.addi(2, 1, 3);
            b.fmac(FpMode::F32, 5, 4, 4);
            b.end();
            b.build()
        };
        let p = build(7);
        let a = DecodedProgram::decode(&p).fingerprint();
        // Decoding the same program again reproduces the fingerprint.
        assert_eq!(a, DecodedProgram::decode(&p).fingerprint());
        // An identically-built program fingerprints equal.
        assert_eq!(a, DecodedProgram::decode(&build(7)).fingerprint());
        // A one-immediate change is a different program.
        assert_ne!(a, DecodedProgram::decode(&build(8)).fingerprint());
        // The empty stream hashes to the FNV-1a offset basis.
        let empty = DecodedProgram { insns: Vec::new(), local_run_len: Vec::new() };
        assert_eq!(empty.fingerprint(), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn local_run_lengths_stop_at_contention_points() {
        let mut b = ProgramBuilder::new("runs");
        b.li(1, 7); // 0: local
        b.addi(2, 1, 1); // 1: local
        b.lw(3, 1, 0); // 2: Load — contention point
        b.addi(4, 4, 1); // 3: local
        b.barrier(); // 4: contention point
        b.end(); // 5: local (End)
        let d = DecodedProgram::decode(&b.build());
        assert_eq!(d.local_run_len, vec![2, 1, 0, 1, 0, 1]);
        // The table is exactly the LOCAL flag in run-length form.
        for (pc, i) in d.insns.iter().enumerate() {
            assert_eq!(d.local_run_len[pc] != 0, i.has(flag::LOCAL), "pc {pc}");
        }
    }

    /// Fingerprint satellite: the hash is order-sensitive — two programs
    /// holding the same multiset of instructions in different orders must
    /// not collide.
    #[test]
    fn fingerprint_is_order_sensitive() {
        let build = |swapped: bool| {
            let mut b = ProgramBuilder::new("ord");
            if swapped {
                b.addi(2, 1, 3);
                b.li(1, 7);
            } else {
                b.li(1, 7);
                b.addi(2, 1, 3);
            }
            b.end();
            b.build()
        };
        assert_ne!(
            DecodedProgram::decode(&build(false)).fingerprint(),
            DecodedProgram::decode(&build(true)).fingerprint(),
            "reordered instruction streams must fingerprint differently"
        );
    }

    /// Fingerprint satellite: repeated predecode runs of one program —
    /// including decodes of independently rebuilt but identical programs —
    /// always reproduce the same hash.
    #[test]
    fn fingerprint_is_stable_across_predecode_runs() {
        let build = || {
            let mut b = ProgramBuilder::new("stab");
            b.li(1, 3);
            b.hwloop(1);
            b.fmac(FpMode::VecF16, 5, 4, 4);
            b.hwloop_end();
            b.barrier();
            b.end();
            b.build()
        };
        let p = build();
        let first = DecodedProgram::decode(&p).fingerprint();
        for _ in 0..10 {
            assert_eq!(DecodedProgram::decode(&p).fingerprint(), first);
            assert_eq!(DecodedProgram::decode(&build()).fingerprint(), first);
        }
    }

    /// Fingerprint satellite: the structural encoding is stable and
    /// collision-free across the 40-program smoke set (8 benchmarks × 5
    /// precision rungs) — exactly the key space the measurement cache and
    /// the compiled tier's code cache operate over.
    #[test]
    fn fingerprints_stable_and_collision_free_across_smoke_set() {
        use crate::config::ClusterConfig;
        use crate::kernels::{Benchmark, Variant};
        let cfg = ClusterConfig::new(8, 4, 1);
        let mut seen: std::collections::HashMap<u64, String> = std::collections::HashMap::new();
        for bench in Benchmark::all() {
            for variant in Variant::all() {
                let w = bench.build(variant, &cfg);
                let fp = DecodedProgram::decode(&w.program).fingerprint();
                // Stable: an independently rebuilt, re-decoded instance of
                // the same workload reproduces the hash.
                let again =
                    DecodedProgram::decode(&bench.build(variant, &cfg).program).fingerprint();
                assert_eq!(fp, again, "{}: fingerprint not reproducible", w.name);
                if let Some(prev) = seen.insert(fp, w.name.clone()) {
                    panic!("fingerprint collision between {prev} and {}", w.name);
                }
            }
        }
        assert_eq!(seen.len(), 40, "smoke set must yield 40 distinct code-cache keys");
    }

    /// Fingerprint satellite: the encoding distinguishes fields with equal
    /// bit patterns in different roles — a register operand and an
    /// immediate operand of the same value are different programs, which a
    /// separator-joined textual rendering could only guarantee by accident.
    #[test]
    fn fingerprint_distinguishes_operand_kinds() {
        let build = |reg_rhs: bool| {
            let mut b = ProgramBuilder::new("opk");
            b.li(1, 5);
            if reg_rhs {
                b.add(2, 1, 3); // rhs = Operand::Reg(3)
            } else {
                b.addi(2, 1, 3); // rhs = Operand::Imm(3)
            }
            b.end();
            b.build()
        };
        assert_ne!(
            DecodedProgram::decode(&build(true)).fingerprint(),
            DecodedProgram::decode(&build(false)).fingerprint(),
            "Reg(3) and Imm(3) operands must not collide"
        );
    }

    #[test]
    fn vec_flag_only_on_datapath_ops() {
        let mut b = ProgramBuilder::new("vec");
        b.fadd(FpMode::VecF16, 1, 2, 3); // datapath, vector
        b.fadd(FpMode::F32, 4, 5, 6); // datapath, scalar
        b.vshuffle(7, 1, 0); // permute (VecF16 mode but ALU class)
        b.end();
        let d = DecodedProgram::decode(&b.build());
        assert!(d.insns[0].has(flag::VEC));
        assert!(!d.insns[1].has(flag::VEC));
        assert!(!d.insns[2].has(flag::VEC));
    }
}
