//! Instruction set + program builder for the simulated RI5CY-like cores.
//!
//! See [`insn`] for the instruction definitions (RV32IM + Xpulp post-
//! increment / hardware loops + FPnew smallFloat scalar/SIMD ops) and
//! [`builder`] for the assembler-style DSL the benchmark kernels use.

pub mod builder;
pub mod decoded;
pub mod insn;

pub use builder::{regs, MarkerOp, Program, ProgramBuilder};
pub use decoded::{DecodedInsn, DecodedProgram, OpClass};
pub use insn::{AluOp, AmoOp, BrCond, FpOp, Insn, MemSize, Operand, Reg};
