//! Instruction definitions for the simulated cores.
//!
//! The instruction set models what the paper's extended GCC toolchain emits
//! for RI5CY + Xpulp + smallFloat: RV32IM base ops, post-increment
//! loads/stores, hardware loops, and the FPnew scalar / packed-SIMD /
//! cast-and-pack FP operations (§3.2, §4). Instructions are structured enum
//! values, not encoded words — the simulator is cycle-accurate at the
//! microarchitectural level, not bit-accurate at the encoding level.

use crate::transfp::{CmpPred, FpMode};

/// Architectural register id (x0..x31; x0 is hardwired zero).
pub type Reg = u8;

/// Second ALU operand: register or immediate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    Reg(Reg),
    Imm(i32),
}

/// Integer ALU operations (single cycle on RI5CY, except Div/Rem).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Srl,
    Sra,
    And,
    Or,
    Xor,
    Slt,
    Sltu,
    /// 32×32→32 multiply (single cycle on RI5CY).
    Mul,
    /// Signed divide (multi-cycle iterative).
    Div,
    /// Signed remainder (multi-cycle iterative).
    Rem,
    /// Xpulp `p.min` / `p.max` (signed).
    Min,
    Max,
    /// Xpulp `p.abs`.
    Abs,
    /// Xpulp `p.mac`: rd += rs1 * rs2 (single cycle).
    Mac,
}

/// Atomic read-modify-write operation on a TCDM word (single bank access).
/// Models the RV32A-style atomics the PULP cluster supports inside the
/// TCDM — the parallel runtime's work-sharing scheduler is built on them
/// (`amoadd.w` for chunk self-scheduling, `amoswap.w` for the guided-
/// schedule lock).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AmoOp {
    /// `rd = mem[addr]; mem[addr] += rs` (fetch-and-add).
    Add,
    /// `rd = mem[addr]; mem[addr] = rs` (swap — test-and-set locks).
    Swap,
}

/// Memory access width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemSize {
    Word,
    Half,
    HalfU,
    Byte,
    ByteU,
}

/// Branch conditions (RV32I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BrCond {
    Eq,
    Ne,
    Lt,
    Ge,
    Ltu,
    Geu,
}

/// Floating-point operations executed on the (possibly shared) FPU, the
/// DIV-SQRT block, or — for moves/casts — the FPU's non-computational path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FpOp {
    /// `fadd` / `vfadd`.
    Add,
    /// `fsub` / `vfsub`.
    Sub,
    /// `fmul` / `vfmul`.
    Mul,
    /// Fused multiply-accumulate, destination is the accumulator:
    /// `rd = rs1 * rs2 + rd` (`fmadd` / `vfmac`). 2 flops/lane.
    Mac,
    /// Widening multi-format FMA: 16-bit `rs1 × rs2` + f32 `rd` → f32 `rd`
    /// (`fmac.s.h`). Mode gives the source format. 2 flops.
    MacWiden,
    /// Expanding SIMD dot product `rd += rs1·rs2` with f32 accumulator
    /// (`vfdotpex.s.{h,ah}`). 4 flops.
    DotpWiden,
    Min,
    Max,
    /// Comparison writing 0/1 (scalar) or lane masks (vector).
    Cmp(CmpPred),
    /// `fdiv` — executed on the shared iterative DIV-SQRT block.
    Div,
    /// `fsqrt` (rs2 ignored) — shared DIV-SQRT block.
    Sqrt,
    /// Sign injection: negate (`fsgnjn rd, rs1, rs1`).
    Neg,
    /// Sign injection: absolute value.
    AbsF,
    /// int → fp (`fcvt.X.w`).
    FromInt,
    /// fp → int, RTZ (`fcvt.w.X`).
    ToInt,
    /// f32 → 16-bit scalar (mode selects format) — `fcvt.h.s`.
    CvtDown,
    /// 16-bit scalar → f32 — `fcvt.s.h`.
    CvtUp,
    /// Cast-and-pack: two f32 sources → both lanes (`vfcpka.X.s`).
    Cpka,
    /// SIMD shuffle; `rs2` is an immediate-selected lane permutation 0..=3.
    Shuffle,
    /// Pack lane0 of rs1 and lane0 of rs2.
    PackLo,
    /// Pack lane1 of rs1 and lane1 of rs2.
    PackHi,
}

impl FpOp {
    /// Flops contributed per lane executed (FMA-class ops count 2).
    pub fn flops_per_lane(&self) -> u64 {
        match self {
            FpOp::Add | FpOp::Sub | FpOp::Mul | FpOp::Min | FpOp::Max => 1,
            FpOp::Mac | FpOp::MacWiden => 2,
            // DotpWiden does 2 mults + 2 adds across its lanes; counted once
            // at the instruction level (lanes() reports 1 for the accumulator
            // view), so report 4 here.
            FpOp::DotpWiden => 4,
            FpOp::Div | FpOp::Sqrt => 1,
            // Comparisons, moves, casts and packs are not counted as flops —
            // matching how Gflop/s is accounted in the paper's benchmarks.
            _ => 0,
        }
    }

    /// True if the op runs on the shared iterative DIV-SQRT block instead of
    /// the FPU datapath.
    pub fn is_divsqrt(&self) -> bool {
        matches!(self, FpOp::Div | FpOp::Sqrt)
    }

    /// True for lane permutations executed by the core's integer-SIMD ALU
    /// (Xpulp `pv.shuffle` / `pv.pack*`), which never touch the FPU — they
    /// count as integer instructions in the Table 3 intensities.
    pub fn is_alu_class(&self) -> bool {
        matches!(self, FpOp::Shuffle | FpOp::PackLo | FpOp::PackHi)
    }

    /// True if this op reads `rd` as an accumulator input.
    pub fn reads_rd(&self) -> bool {
        matches!(self, FpOp::Mac | FpOp::MacWiden | FpOp::DotpWiden)
    }
}

/// One instruction. `Label`s have been resolved to absolute instruction
/// indices by the [`super::builder::ProgramBuilder`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Insn {
    /// Integer ALU op `rd = rs1 <op> rhs`.
    Alu { op: AluOp, rd: Reg, rs1: Reg, rhs: Operand },
    /// Load immediate (`lui+addi` pair collapsed; 1 cycle).
    Li { rd: Reg, imm: u32 },
    /// Load `rd = mem[rs1 + offset]`; `post_inc != 0` adds Xpulp
    /// post-increment addressing: `rs1 += post_inc` after the access.
    Load { rd: Reg, base: Reg, offset: i32, post_inc: i32, size: MemSize },
    /// Store `mem[rs1 + offset] = rs2`, with optional post-increment.
    Store { rs: Reg, base: Reg, offset: i32, post_inc: i32, size: MemSize },
    /// Conditional branch to absolute instruction index `target`.
    Branch { cond: BrCond, rs1: Reg, rs2: Reg, target: u32 },
    /// Unconditional jump.
    Jump { target: u32 },
    /// Xpulp hardware loop: execute the body `[start, end)` `count`(register)
    /// times with zero-overhead back-edges (`lp.setup`).
    HwLoop { count: Reg, start: u32, end: u32 },
    /// Floating-point operation. `rs3` is only used by ops reading rd
    /// implicitly via `reads_rd` (kept for clarity in traces).
    Fp { op: FpOp, mode: FpMode, rd: Reg, rs1: Reg, rs2: Reg },
    /// Atomic read-modify-write on a TCDM word: `rd = mem[rs1 + offset]`
    /// and the location is updated per `op` with `rs` — one bank access,
    /// indivisible under the interconnect's per-cycle bank grant.
    Amo { op: AmoOp, rd: Reg, base: Reg, offset: i32, rs: Reg },
    /// Event-unit barrier: sleep until all cores arrive (§3.1 Event Unit).
    Barrier,
    /// Event unit: sleep until software event line `ev` is raised (PULP
    /// `p.elw`-style). A buffered event is consumed without sleeping.
    WaitEvent { ev: u8 },
    /// Event unit: raise software event line `ev` for every core (waiters
    /// wake after the event unit's fixed latency; non-waiters buffer it).
    SetEvent { ev: u8 },
    /// Terminate this core's execution.
    End,
}

impl Insn {
    /// True if the instruction accesses memory (memory intensity): loads,
    /// stores, and TCDM atomics.
    pub fn is_mem(&self) -> bool {
        matches!(self, Insn::Load { .. } | Insn::Store { .. } | Insn::Amo { .. })
    }

    /// True if the instruction occupies the FPU or DIV-SQRT (FP intensity).
    pub fn is_fp(&self) -> bool {
        matches!(self, Insn::Fp { .. })
    }

    /// The register read set consulted by the issue scoreboard, in check
    /// order (the order determines stall attribution on ties). This is the
    /// single source of truth shared by the reference engine's
    /// `operands_ready` and the predecode pass.
    pub fn read_regs(&self) -> ([Reg; 3], u8) {
        let mut regs = [0u8; 3];
        let mut n = 0u8;
        let mut push = |r: Reg| {
            regs[n as usize] = r;
            n += 1;
        };
        match self {
            Insn::Alu { rs1, rhs, .. } => {
                push(*rs1);
                if let Operand::Reg(r) = rhs {
                    push(*r);
                }
            }
            Insn::Li { .. } => {}
            Insn::Load { base, .. } => push(*base),
            Insn::Store { rs, base, .. } => {
                push(*rs);
                push(*base);
            }
            Insn::Branch { rs1, rs2, .. } => {
                push(*rs1);
                push(*rs2);
            }
            Insn::Jump { .. } | Insn::Barrier | Insn::WaitEvent { .. } | Insn::SetEvent { .. }
            | Insn::End => {}
            Insn::Amo { rs, base, .. } => {
                push(*rs);
                push(*base);
            }
            Insn::HwLoop { count, .. } => push(*count),
            Insn::Fp { op, rd, rs1, rs2, .. } => {
                push(*rs1);
                // Shuffle carries an immediate in the rs2 slot; unary ops
                // and casts ignore it.
                if !matches!(
                    op,
                    FpOp::Shuffle
                        | FpOp::Sqrt
                        | FpOp::Neg
                        | FpOp::AbsF
                        | FpOp::FromInt
                        | FpOp::ToInt
                        | FpOp::CvtDown
                        | FpOp::CvtUp
                ) {
                    push(*rs2);
                }
                if op.reads_rd() {
                    push(*rd);
                }
            }
        }
        (regs, n)
    }

    /// Does the instruction write an integer/FP destination register?
    /// (Write-back port model of §5.3.3; post-increment stores update the
    /// base register.)
    pub fn writes_int_reg(&self) -> bool {
        match self {
            Insn::Alu { .. } | Insn::Li { .. } | Insn::Load { .. } | Insn::Amo { .. } => true,
            Insn::Store { post_inc, .. } => *post_inc != 0,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_accounting() {
        assert_eq!(FpOp::Add.flops_per_lane(), 1);
        assert_eq!(FpOp::Mac.flops_per_lane(), 2);
        assert_eq!(FpOp::DotpWiden.flops_per_lane(), 4);
        assert_eq!(FpOp::Cpka.flops_per_lane(), 0);
        assert_eq!(FpOp::Cmp(CmpPred::Lt).flops_per_lane(), 0);
    }

    #[test]
    fn classification() {
        assert!(FpOp::Div.is_divsqrt());
        assert!(FpOp::Sqrt.is_divsqrt());
        assert!(!FpOp::Mac.is_divsqrt());
        assert!(FpOp::Mac.reads_rd());
        assert!(!FpOp::Add.reads_rd());
        let ld = Insn::Load { rd: 1, base: 2, offset: 0, post_inc: 4, size: MemSize::Word };
        assert!(ld.is_mem() && !ld.is_fp());
        let fp = Insn::Fp { op: FpOp::Add, mode: FpMode::F32, rd: 1, rs1: 2, rs2: 3 };
        assert!(fp.is_fp() && !fp.is_mem());
    }

    #[test]
    fn read_sets_and_write_flags() {
        let (r, n) = Insn::Alu { op: AluOp::Add, rd: 1, rs1: 2, rhs: Operand::Reg(3) }.read_regs();
        assert_eq!((&r[..n as usize], n), (&[2u8, 3][..], 2));
        let (r, n) = Insn::Alu { op: AluOp::Add, rd: 1, rs1: 2, rhs: Operand::Imm(7) }.read_regs();
        assert_eq!((&r[..n as usize], n), (&[2u8][..], 1));
        let (r, n) = Insn::Store { rs: 4, base: 5, offset: 0, post_inc: 4, size: MemSize::Word }
            .read_regs();
        assert_eq!(&r[..n as usize], &[4u8, 5]);
        // FMA reads rd as the accumulator; shuffle's rs2 is an immediate.
        let (r, n) =
            Insn::Fp { op: FpOp::Mac, mode: FpMode::F32, rd: 6, rs1: 7, rs2: 8 }.read_regs();
        assert_eq!(&r[..n as usize], &[7u8, 8, 6]);
        let (r, n) =
            Insn::Fp { op: FpOp::Shuffle, mode: FpMode::VecF16, rd: 6, rs1: 7, rs2: 3 }.read_regs();
        assert_eq!(&r[..n as usize], &[7u8]);

        assert!(Insn::Li { rd: 1, imm: 0 }.writes_int_reg());
        assert!(!Insn::Store { rs: 1, base: 2, offset: 0, post_inc: 0, size: MemSize::Word }
            .writes_int_reg());
        assert!(Insn::Store { rs: 1, base: 2, offset: 0, post_inc: 4, size: MemSize::Word }
            .writes_int_reg());
        assert!(!Insn::Barrier.writes_int_reg());
    }

    #[test]
    fn amo_and_event_classification() {
        let amo = Insn::Amo { op: AmoOp::Add, rd: 3, base: 4, offset: 0, rs: 5 };
        // Atomics read (rs, base) like a store, write rd like a load, and
        // count as memory traffic.
        let (r, n) = amo.read_regs();
        assert_eq!(&r[..n as usize], &[5u8, 4]);
        assert!(amo.writes_int_reg());
        assert!(amo.is_mem() && !amo.is_fp());

        for i in [Insn::WaitEvent { ev: 3 }, Insn::SetEvent { ev: 3 }] {
            let (_, n) = i.read_regs();
            assert_eq!(n, 0, "{i:?} reads no registers");
            assert!(!i.writes_int_reg());
            assert!(!i.is_mem() && !i.is_fp());
        }
    }
}
