//! Format conversions: scalar casts, int↔fp, and the paper's
//! **cast-and-pack** instructions (`vfcpka.{h,ah}.s` &c.) that convert two
//! binary32 scalars and deposit them into adjacent lanes of a packed vector
//! in one instruction — removing the "convert scalars and assemble vectors"
//! bottleneck discussed in §4 of the paper.

use super::simd::{pack2, unpack2};
use super::spec::FpSpec;

/// binary32 → 16-bit format, round to nearest even.
#[inline]
pub fn f32_to_16(spec: &FpSpec, a: u32) -> u16 {
    spec.from_f64(f32::from_bits(a) as f64)
}

/// 16-bit format → binary32 (exact).
#[inline]
pub fn f16_to_32(spec: &FpSpec, a: u16) -> u32 {
    (spec.to_f64(a) as f32).to_bits()
}

/// 16-bit → 16-bit cross-format conversion (e.g. float16 → bfloat16).
#[inline]
pub fn f16_to_16(from: &FpSpec, to: &FpSpec, a: u16) -> u16 {
    to.from_f64(from.to_f64(a))
}

/// Signed i32 → binary32 (RNE — `fcvt.s.w`).
#[inline]
pub fn i32_to_f32(a: u32) -> u32 {
    (a as i32 as f32).to_bits()
}

/// binary32 → signed i32, round toward zero (`fcvt.w.s` RTZ), saturating per
/// RISC-V semantics; NaN → i32::MAX.
#[inline]
pub fn f32_to_i32(a: u32) -> u32 {
    let x = f32::from_bits(a);
    if x.is_nan() {
        return i32::MAX as u32;
    }
    let t = x.trunc();
    if t >= i32::MAX as f32 {
        i32::MAX as u32
    } else if t <= i32::MIN as f32 {
        i32::MIN as u32
    } else {
        (t as i32) as u32
    }
}

/// Signed i32 → 16-bit format.
#[inline]
pub fn i32_to_16(spec: &FpSpec, a: u32) -> u16 {
    spec.from_f64(a as i32 as f64)
}

/// 16-bit format → signed i32 (RTZ, saturating).
#[inline]
pub fn f16_to_i32(spec: &FpSpec, a: u16) -> u32 {
    if spec.is_nan(a) {
        return i32::MAX as u32;
    }
    let t = spec.to_f64(a).trunc();
    if t >= i32::MAX as f64 {
        i32::MAX as u32
    } else if t <= i32::MIN as f64 {
        i32::MIN as u32
    } else {
        (t as i32) as u32
    }
}

/// Cast-and-pack **low**: convert f32 scalars `a`, `b` and write them to
/// lanes 0 and 1 of the result (`vfcpka.X.s rd, ra, rb`).
#[inline]
pub fn cpka(spec: &FpSpec, a: u32, b: u32) -> u32 {
    pack2(f32_to_16(spec, a), f32_to_16(spec, b))
}

/// Cast-and-pack keeping the destination's other half — used when assembling
/// vectors incrementally: writes lane0 only.
#[inline]
pub fn cpk_lane0(spec: &FpSpec, dest: u32, a: u32) -> u32 {
    let (_, hi) = unpack2(dest);
    pack2(f32_to_16(spec, a), hi)
}

/// Writes lane1 only.
#[inline]
pub fn cpk_lane1(spec: &FpSpec, dest: u32, a: u32) -> u32 {
    let (lo, _) = unpack2(dest);
    pack2(lo, f32_to_16(spec, a))
}

/// Unpack-and-cast both lanes to two f32 values (lane0, lane1) — the inverse
/// direction, used when a vector result feeds scalar high-precision code.
#[inline]
pub fn vunpack_f32(spec: &FpSpec, v: u32) -> (u32, u32) {
    let (lo, hi) = unpack2(v);
    (f16_to_32(spec, lo), f16_to_32(spec, hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfp::spec::{BF16, F16};

    #[test]
    fn f32_roundtrips_through_16() {
        // Values exactly representable in f16 survive the round trip.
        for v in [0.0f32, 1.0, -2.5, 0.125, 65504.0] {
            let h = f32_to_16(&F16, v.to_bits());
            assert_eq!(f32::from_bits(f16_to_32(&F16, h)), v);
        }
        // bf16 keeps range, loses mantissa.
        let h = f32_to_16(&BF16, 3.0e38f32.to_bits());
        assert!((f32::from_bits(f16_to_32(&BF16, h)) - 3.0e38).abs() < 3.0e36);
    }

    #[test]
    fn cross_format() {
        let h = F16.from_f64(0.1);
        let b = f16_to_16(&F16, &BF16, h);
        // f16(0.1) = 0.0999755859375 = 1.59960937·2⁻⁴; bf16 mantissa steps of
        // 1/128 put the neighbours at 0.099609375 / 0.10009765625, and
        // 76.75/128 rounds up → 0.10009765625.
        assert_eq!(BF16.to_f64(b), 0.10009765625);
    }

    #[test]
    fn int_conversions() {
        assert_eq!(f32::from_bits(i32_to_f32(-7i32 as u32)), -7.0);
        assert_eq!(f32_to_i32((-3.75f32).to_bits()) as i32, -3);
        assert_eq!(f32_to_i32(f32::NAN.to_bits()) as i32, i32::MAX);
        assert_eq!(f32_to_i32(1e20f32.to_bits()) as i32, i32::MAX);
        assert_eq!(F16.to_f64(i32_to_16(&F16, 100u32)), 100.0);
        assert_eq!(f16_to_i32(&F16, F16.from_f64(-2.9)) as i32, -2);
    }

    #[test]
    fn cast_and_pack() {
        let v = cpka(&F16, 1.5f32.to_bits(), (-2.0f32).to_bits());
        let (lo, hi) = vunpack_f32(&F16, v);
        assert_eq!(f32::from_bits(lo), 1.5);
        assert_eq!(f32::from_bits(hi), -2.0);

        let mut d = 0u32;
        d = cpk_lane0(&F16, d, 3.0f32.to_bits());
        d = cpk_lane1(&F16, d, 4.0f32.to_bits());
        assert_eq!(v_lanes(&F16, d), (3.0, 4.0));
    }

    fn v_lanes(spec: &FpSpec, v: u32) -> (f64, f64) {
        let (lo, hi) = crate::transfp::simd::unpack2(v);
        (spec.to_f64(lo), spec.to_f64(hi))
    }
}
