//! Format specifications for the transprecision FP formats supported by the
//! cluster's FPnew-style datapath: IEEE binary32 (`float`), IEEE binary16
//! (`float16`) and bfloat16.
//!
//! 16-bit values are carried as raw `u16` bit patterns. All arithmetic is
//! performed by widening exactly to `f64` (both 16-bit formats embed exactly
//! in binary64), computing, and rounding back with a *single* round-to-
//! nearest-even step implemented over the raw bits (`from_f64`). This mirrors
//! the FPnew datapath, which computes on an internal wide significand and
//! rounds once at the output.

/// A (sign, exponent, mantissa) floating-point format with ≤16 bits total.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpSpec {
    /// Number of exponent bits.
    pub exp_bits: u32,
    /// Number of explicit mantissa (fraction) bits.
    pub man_bits: u32,
}

/// IEEE 754 binary16: 1 + 5 + 10.
pub const F16: FpSpec = FpSpec { exp_bits: 5, man_bits: 10 };
/// bfloat16: 1 + 8 + 7 (same dynamic range as binary32).
pub const BF16: FpSpec = FpSpec { exp_bits: 8, man_bits: 7 };

impl FpSpec {
    /// Exponent bias.
    #[inline]
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Maximum biased exponent value (all-ones = inf/NaN).
    #[inline]
    pub const fn exp_max(&self) -> u32 {
        (1 << self.exp_bits) - 1
    }

    /// Total storage bits (always ≤ 16 here).
    #[inline]
    pub const fn total_bits(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// The canonical quiet NaN bit pattern (sign=0, exp all ones, MSB of mantissa set).
    #[inline]
    pub const fn qnan(&self) -> u16 {
        ((self.exp_max() as u16) << self.man_bits) | (1 << (self.man_bits - 1))
    }

    /// Positive infinity bit pattern.
    #[inline]
    pub const fn inf(&self, negative: bool) -> u16 {
        let mag = (self.exp_max() as u16) << self.man_bits;
        if negative {
            mag | (1 << (self.total_bits() - 1))
        } else {
            mag
        }
    }

    /// Largest finite magnitude bit pattern (positive).
    #[inline]
    pub const fn max_finite(&self) -> u16 {
        (((self.exp_max() - 1) as u16) << self.man_bits) | ((1 << self.man_bits) - 1)
    }

    /// Split a bit pattern into (sign, biased exponent, mantissa).
    #[inline]
    pub fn unpack(&self, bits: u16) -> (bool, u32, u32) {
        let sign = (bits >> (self.total_bits() - 1)) & 1 == 1;
        let exp = ((bits >> self.man_bits) as u32) & self.exp_max();
        let man = (bits as u32) & ((1 << self.man_bits) - 1);
        (sign, exp, man)
    }

    /// Assemble a bit pattern from (sign, biased exponent, mantissa).
    #[inline]
    pub fn pack(&self, sign: bool, exp: u32, man: u32) -> u16 {
        debug_assert!(exp <= self.exp_max());
        debug_assert!(man < (1 << self.man_bits));
        ((sign as u16) << (self.total_bits() - 1)) | ((exp as u16) << self.man_bits) | man as u16
    }

    /// True if `bits` encodes a NaN.
    #[inline]
    pub fn is_nan(&self, bits: u16) -> bool {
        let (_, e, m) = self.unpack(bits);
        e == self.exp_max() && m != 0
    }

    /// True if `bits` encodes ±inf.
    #[inline]
    pub fn is_inf(&self, bits: u16) -> bool {
        let (_, e, m) = self.unpack(bits);
        e == self.exp_max() && m == 0
    }

    /// Exact widening conversion to binary64. Every finite value of both
    /// 16-bit formats is exactly representable in binary64.
    pub fn to_f64(&self, bits: u16) -> f64 {
        let (sign, exp, man) = self.unpack(bits);
        let s = if sign { -1.0 } else { 1.0 };
        if exp == self.exp_max() {
            return if man != 0 {
                f64::NAN
            } else {
                s * f64::INFINITY
            };
        }
        let v = if exp == 0 {
            // Subnormal: man * 2^(1 - bias - man_bits)
            man as f64 * (2.0f64).powi(1 - self.bias() - self.man_bits as i32)
        } else {
            (1.0 + man as f64 / (1u64 << self.man_bits) as f64)
                * (2.0f64).powi(exp as i32 - self.bias())
        };
        s * v
    }

    /// Correctly rounded (round-to-nearest-even) narrowing conversion from
    /// binary64. Handles overflow→inf, subnormals, and signed zeros per
    /// IEEE 754. This is the *single* rounding step of every arithmetic op.
    pub fn from_f64(&self, x: f64) -> u16 {
        if x.is_nan() {
            return self.qnan();
        }
        let xb = x.to_bits();
        let sign = (xb >> 63) & 1 == 1;
        if x.is_infinite() {
            return self.inf(sign);
        }
        let abs = x.abs();
        if abs == 0.0 {
            return self.pack(sign, 0, 0);
        }
        // binary64 fields of |x|
        let ab = abs.to_bits();
        let e64 = ((ab >> 52) & 0x7ff) as i64;
        let m64 = ab & ((1u64 << 52) - 1);
        // Unbiased exponent and 53-bit significand; f64 subnormals are far
        // below the smallest 16-bit subnormal (2^-1022 vs ≥2^-133) → round to 0.
        if e64 == 0 {
            return self.pack(sign, 0, 0);
        }
        let exp = e64 - 1023; // value = 1.m64 * 2^exp
        let sig = (1u64 << 52) | m64; // 53 significant bits

        let bias = self.bias() as i64;
        let emin = 1 - bias; // smallest normal exponent (unbiased)
        // Number of fraction bits to drop from the 52-bit fraction.
        let mut drop = 52 - self.man_bits as i64;
        let mut biased = exp + bias; // tentative biased exponent
        if biased <= 0 {
            // Subnormal (or underflow) in the target format: shift further.
            drop += 1 - biased; // extra shift to align to emin
            biased = 0;
            let _ = emin;
        }
        if drop >= 63 {
            // Way below subnormal range: magnitude < 2^-62 * ulp → rounds to 0
            // (drop=63 means even the round bit is below everything).
            return self.pack(sign, 0, 0);
        }
        let kept = sig >> drop;
        let round_bit = (sig >> (drop - 1)) & 1;
        let sticky = sig & ((1u64 << (drop - 1)) - 1) != 0;
        let mut out = kept;
        if round_bit == 1 && (sticky || (kept & 1) == 1) {
            out += 1; // round to nearest, ties to even
        }
        // `out` holds mantissa with (possibly) the implicit bit at position
        // man_bits (for normals) — handle carries and reassemble.
        let man_mask = (1u64 << self.man_bits) - 1;
        let (final_exp, final_man) = if biased == 0 {
            // Subnormal path: implicit bit absent. A carry into bit man_bits
            // promotes to the smallest normal (exp=1), encoded naturally.
            if out > man_mask {
                (1u32, (out - (man_mask + 1)) as u32)
            } else {
                (0u32, out as u32)
            }
        } else {
            // Normal path: implicit bit present at position man_bits.
            let mut e = biased as u32;
            let mut m = out;
            if m >= (1u64 << (self.man_bits + 1)) {
                // Carry out of the significand: exponent += 1.
                m >>= 1;
                e += 1;
            }
            (e, (m & man_mask) as u32)
        };
        if final_exp >= self.exp_max() {
            return self.inf(sign); // overflow
        }
        self.pack(sign, final_exp, final_man)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_values() {
        // Constants cross-checked against numpy.float16.
        assert_eq!(F16.from_f64(1.0), 0x3C00);
        assert_eq!(F16.from_f64(-2.0), 0xC000);
        assert_eq!(F16.from_f64(0.1), 0x2E66);
        assert_eq!(F16.from_f64(65504.0), 0x7BFF); // max finite
        assert_eq!(F16.from_f64(65520.0), 0x7C00); // rounds to inf
        assert_eq!(F16.from_f64(65519.9), 0x7BFF); // just below tie
        assert_eq!(F16.from_f64(5.960464477539063e-08), 0x0001); // min subnormal
        assert_eq!(F16.from_f64(2.980232238769531e-08), 0x0000); // tie → even (0)
        assert_eq!(F16.from_f64(2.98023223876953125e-08 * 1.0000001), 0x0001);
        assert_eq!(F16.from_f64(6.103515625e-05), 0x0400); // min normal
        assert_eq!(F16.from_f64(f64::INFINITY), 0x7C00);
        assert_eq!(F16.from_f64(-f64::INFINITY), 0xFC00);
        assert!(F16.is_nan(F16.from_f64(f64::NAN)));
        assert_eq!(F16.from_f64(-0.0).to_owned(), 0x8000);
    }

    #[test]
    fn bf16_known_values() {
        // bf16 is the top half of f32; cross-checked with ml_dtypes.bfloat16.
        assert_eq!(BF16.from_f64(1.0), 0x3F80);
        assert_eq!(BF16.from_f64(3.140625), 0x4049);
        assert_eq!(BF16.from_f64(0.1), 0x3DCD);
        assert_eq!(BF16.from_f64(3.3895313892515355e38), 0x7F7F); // max finite
        assert_eq!(BF16.from_f64(3.5e38), 0x7F80); // inf
        assert_eq!(BF16.from_f64(f64::NEG_INFINITY), 0xFF80);
    }

    #[test]
    fn roundtrip_all_finite_f16() {
        for bits in 0u16..=0xFFFF {
            if F16.is_nan(bits) {
                continue;
            }
            let x = F16.to_f64(bits);
            assert_eq!(F16.from_f64(x), bits, "roundtrip failed for {bits:#06x} = {x}");
        }
    }

    #[test]
    fn roundtrip_all_finite_bf16() {
        for bits in 0u16..=0xFFFF {
            if BF16.is_nan(bits) {
                continue;
            }
            let x = BF16.to_f64(bits);
            assert_eq!(BF16.from_f64(x), bits, "roundtrip failed for {bits:#06x} = {x}");
        }
    }

    #[test]
    fn bf16_matches_f32_truncation_semantics() {
        // For every bf16 value, to_f64 must equal the f32 with the same top bits.
        for bits in 0u16..=0xFFFF {
            if BF16.is_nan(bits) {
                continue;
            }
            let via_f32 = f32::from_bits((bits as u32) << 16) as f64;
            let ours = BF16.to_f64(bits);
            if via_f32.is_infinite() {
                assert!(ours.is_infinite() && ours.signum() == via_f32.signum());
            } else {
                assert_eq!(ours, via_f32, "bits={bits:#06x}");
            }
        }
    }

    #[test]
    fn monotone_rounding_f16() {
        // from_f64 must be monotone non-decreasing over positive reals.
        let mut prev = 0u16;
        let mut x = 1e-9f64;
        while x < 1e5 {
            let b = F16.from_f64(x);
            if !F16.is_nan(b) && !F16.is_inf(b) {
                assert!(b >= prev, "non-monotone at {x}");
                prev = b;
            }
            x *= 1.001;
        }
    }

    #[test]
    fn spec_constants() {
        assert_eq!(F16.bias(), 15);
        assert_eq!(BF16.bias(), 127);
        assert_eq!(F16.qnan(), 0x7E00);
        assert_eq!(BF16.qnan(), 0x7FC0);
        assert_eq!(F16.max_finite(), 0x7BFF);
        assert_eq!(BF16.max_finite(), 0x7F7F);
        assert_eq!(F16.total_bits(), 16);
        assert_eq!(BF16.total_bits(), 16);
    }
}
