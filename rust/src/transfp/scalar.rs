//! Scalar transprecision arithmetic on raw bit patterns.
//!
//! 16-bit ops widen exactly to binary64, compute (FMA fused via
//! `f64::mul_add`), and round once via [`FpSpec::from_f64`]. binary32 ops use
//! native `f32` arithmetic (`f32::mul_add` for FMA) — IEEE correct on every
//! platform Rust targets.

use super::spec::FpSpec;

// ---------------------------------------------------------------- binary32

/// f32 bit-pattern add.
#[inline]
pub fn add32(a: u32, b: u32) -> u32 {
    (f32::from_bits(a) + f32::from_bits(b)).to_bits()
}

/// f32 bit-pattern subtract.
#[inline]
pub fn sub32(a: u32, b: u32) -> u32 {
    (f32::from_bits(a) - f32::from_bits(b)).to_bits()
}

/// f32 bit-pattern multiply.
#[inline]
pub fn mul32(a: u32, b: u32) -> u32 {
    (f32::from_bits(a) * f32::from_bits(b)).to_bits()
}

/// f32 fused multiply-add: `a*b + c` with a single rounding.
#[inline]
pub fn fma32(a: u32, b: u32, c: u32) -> u32 {
    f32::from_bits(a)
        .mul_add(f32::from_bits(b), f32::from_bits(c))
        .to_bits()
}

/// f32 divide.
#[inline]
pub fn div32(a: u32, b: u32) -> u32 {
    (f32::from_bits(a) / f32::from_bits(b)).to_bits()
}

/// f32 square root.
#[inline]
pub fn sqrt32(a: u32) -> u32 {
    f32::from_bits(a).sqrt().to_bits()
}

/// IEEE minimumNumber (NaN loses against a number), as FPnew implements FMIN.
#[inline]
pub fn min32(a: u32, b: u32) -> u32 {
    let (x, y) = (f32::from_bits(a), f32::from_bits(b));
    if x.is_nan() {
        b
    } else if y.is_nan() {
        a
    } else if x < y || (x == y && x.is_sign_negative()) {
        a
    } else {
        b
    }
}

/// IEEE maximumNumber.
#[inline]
pub fn max32(a: u32, b: u32) -> u32 {
    let (x, y) = (f32::from_bits(a), f32::from_bits(b));
    if x.is_nan() {
        b
    } else if y.is_nan() {
        a
    } else if x > y || (x == y && x.is_sign_positive()) {
        a
    } else {
        b
    }
}

/// Comparison predicates used by the ISA's `feq/flt/fle` (return 0/1).
#[inline]
pub fn cmp32(a: u32, b: u32, pred: CmpPred) -> u32 {
    let (x, y) = (f32::from_bits(a), f32::from_bits(b));
    let r = match pred {
        CmpPred::Eq => x == y,
        CmpPred::Lt => x < y,
        CmpPred::Le => x <= y,
    };
    r as u32
}

/// Floating-point comparison predicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpPred {
    Eq,
    Lt,
    Le,
}

// ---------------------------------------------------------------- 16-bit

/// 16-bit add in format `spec`.
#[inline]
pub fn add16(spec: &FpSpec, a: u16, b: u16) -> u16 {
    spec.from_f64(spec.to_f64(a) + spec.to_f64(b))
}

/// 16-bit subtract.
#[inline]
pub fn sub16(spec: &FpSpec, a: u16, b: u16) -> u16 {
    spec.from_f64(spec.to_f64(a) - spec.to_f64(b))
}

/// 16-bit multiply. The binary64 product of two ≤11-bit significands is
/// exact, so the single `from_f64` rounding is the correctly rounded result.
#[inline]
pub fn mul16(spec: &FpSpec, a: u16, b: u16) -> u16 {
    spec.from_f64(spec.to_f64(a) * spec.to_f64(b))
}

/// 16-bit fused multiply-add `a*b + c`.
#[inline]
pub fn fma16(spec: &FpSpec, a: u16, b: u16, c: u16) -> u16 {
    spec.from_f64(spec.to_f64(a).mul_add(spec.to_f64(b), spec.to_f64(c)))
}

/// 16-bit divide (iterative DIV-SQRT block in hardware; numerics here).
#[inline]
pub fn div16(spec: &FpSpec, a: u16, b: u16) -> u16 {
    spec.from_f64(spec.to_f64(a) / spec.to_f64(b))
}

/// 16-bit square root.
#[inline]
pub fn sqrt16(spec: &FpSpec, a: u16) -> u16 {
    spec.from_f64(spec.to_f64(a).sqrt())
}

/// 16-bit minimumNumber.
#[inline]
pub fn min16(spec: &FpSpec, a: u16, b: u16) -> u16 {
    if spec.is_nan(a) {
        return b;
    }
    if spec.is_nan(b) {
        return a;
    }
    let (x, y) = (spec.to_f64(a), spec.to_f64(b));
    if x < y || (x == y && (a >> 15) == 1) {
        a
    } else {
        b
    }
}

/// 16-bit maximumNumber.
#[inline]
pub fn max16(spec: &FpSpec, a: u16, b: u16) -> u16 {
    if spec.is_nan(a) {
        return b;
    }
    if spec.is_nan(b) {
        return a;
    }
    let (x, y) = (spec.to_f64(a), spec.to_f64(b));
    if x > y || (x == y && (a >> 15) == 0) {
        a
    } else {
        b
    }
}

/// 16-bit comparison (quiet; NaN compares false).
#[inline]
pub fn cmp16(spec: &FpSpec, a: u16, b: u16, pred: CmpPred) -> u32 {
    if spec.is_nan(a) || spec.is_nan(b) {
        return 0;
    }
    let (x, y) = (spec.to_f64(a), spec.to_f64(b));
    let r = match pred {
        CmpPred::Eq => x == y,
        CmpPred::Lt => x < y,
        CmpPred::Le => x <= y,
    };
    r as u32
}

/// Multi-format FMA: 16-bit `a`, `b` in `spec`, 32-bit accumulator `c`,
/// 32-bit result — FPnew's widening FMA (e.g. `fmac.s.h`), the key op for
/// "accumulate in higher precision" near-sensor patterns.
#[inline]
pub fn fma_widen(spec: &FpSpec, a: u16, b: u16, c: u32) -> u32 {
    let p = spec.to_f64(a).mul_add(spec.to_f64(b), f32::from_bits(c) as f64);
    // Single rounding f64→f32: the product is exact in f64 and the add can
    // carry at most 1 ulp of f64 error far below f32 precision.
    (p as f32).to_bits()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfp::spec::{BF16, F16};

    #[test]
    fn f32_ops_are_native() {
        assert_eq!(f32::from_bits(add32(1.5f32.to_bits(), 2.25f32.to_bits())), 3.75);
        assert_eq!(
            f32::from_bits(fma32(3.0f32.to_bits(), 4.0f32.to_bits(), 0.5f32.to_bits())),
            12.5
        );
        assert_eq!(f32::from_bits(sqrt32(9.0f32.to_bits())), 3.0);
        assert_eq!(cmp32(1.0f32.to_bits(), 2.0f32.to_bits(), CmpPred::Lt), 1);
    }

    #[test]
    fn f16_basic_arith() {
        let one = F16.from_f64(1.0);
        let tenth = F16.from_f64(0.1);
        // 0.1f16 = 0.0999755859375; +1 rounds to 1.099609375 = 0x3C66
        assert_eq!(add16(&F16, one, tenth), 0x3C66);
        assert_eq!(mul16(&F16, F16.from_f64(3.0), F16.from_f64(4.0)), F16.from_f64(12.0));
        // Saturating behaviour: overflow → inf
        let big = F16.from_f64(60000.0);
        assert!(F16.is_inf(add16(&F16, big, big)));
    }

    #[test]
    fn f16_fma_single_rounding() {
        // Triple (found by exhaustive search, cross-checked with numpy) where
        // the fused result differs from mul-then-add by 1 ulp:
        // a=1.095703125, b=-1.841796875, c=-3.671875.
        let (a, b, c) = (15458u16, 48990u16, 50008u16);
        let fused = fma16(&F16, a, b, c);
        assert_eq!(fused, 50609, "fused must keep the low product bits");
        let lossy = add16(&F16, mul16(&F16, a, b), c);
        assert_eq!(lossy, 50608);
        assert_ne!(fused, lossy);
        // And the fused result matches the exact f64 computation rounded once.
        let exact = F16.to_f64(a).mul_add(F16.to_f64(b), F16.to_f64(c));
        assert_eq!(fused, F16.from_f64(exact));
    }

    #[test]
    fn bf16_arith() {
        let x = BF16.from_f64(1.5);
        let y = BF16.from_f64(2.5);
        assert_eq!(BF16.to_f64(mul16(&BF16, x, y)), 3.75);
        // bf16 keeps f32 range: 1e38 * 2 overflows to inf
        let big = BF16.from_f64(2.0e38);
        assert!(BF16.is_inf(add16(&BF16, big, big)));
    }

    #[test]
    fn widening_fma() {
        // f16 x f16 + f32 -> f32 keeps precision a pure-f16 FMA would lose.
        let a = F16.from_f64(0.1);
        let b = F16.from_f64(0.1);
        let acc = 100.0f32.to_bits();
        let r = f32::from_bits(fma_widen(&F16, a, b, acc));
        let expect = (F16.to_f64(a) * F16.to_f64(b) + 100.0) as f32;
        assert_eq!(r, expect);
    }

    #[test]
    fn min_max_nan_handling() {
        let nan = F16.qnan();
        let one = F16.from_f64(1.0);
        assert_eq!(min16(&F16, nan, one), one);
        assert_eq!(max16(&F16, one, nan), one);
        assert_eq!(cmp16(&F16, nan, one, CmpPred::Le), 0);
        // signed zero ordering
        let pz = F16.from_f64(0.0);
        let nz = F16.from_f64(-0.0);
        assert_eq!(min16(&F16, pz, nz), nz);
        assert_eq!(max16(&F16, pz, nz), pz);
    }

    #[test]
    fn div_sqrt_numerics() {
        assert_eq!(F16.to_f64(div16(&F16, F16.from_f64(1.0), F16.from_f64(3.0))), F16.to_f64(F16.from_f64(1.0 / 3.0)));
        assert_eq!(F16.to_f64(sqrt16(&F16, F16.from_f64(2.0))), F16.to_f64(F16.from_f64(2f64.sqrt())));
        assert!(F16.is_nan(sqrt16(&F16, F16.from_f64(-1.0))));
    }
}
