//! Packed-SIMD 2×16-bit vector operations on the 32-bit datapath.
//!
//! A `u32` register holds two 16-bit lanes: lane 0 in bits [15:0], lane 1 in
//! bits [31:16] — the layout of the Xpulp `vfALU.h` / `vfALU.ah` (bfloat16)
//! instruction families. Each lane rounds independently, exactly like two
//! FPnew slices operating in parallel.

use super::scalar;
use super::spec::FpSpec;

/// Split a packed register into (lane0, lane1).
#[inline]
pub fn unpack2(v: u32) -> (u16, u16) {
    (v as u16, (v >> 16) as u16)
}

/// Assemble (lane0, lane1) into a packed register.
#[inline]
pub fn pack2(lo: u16, hi: u16) -> u32 {
    (lo as u32) | ((hi as u32) << 16)
}

/// Lane-wise binary op helper.
#[inline]
fn map2(a: u32, b: u32, f: impl Fn(u16, u16) -> u16) -> u32 {
    let (a0, a1) = unpack2(a);
    let (b0, b1) = unpack2(b);
    pack2(f(a0, b0), f(a1, b1))
}

/// `vfadd.{h,ah}` — lane-wise add.
#[inline]
pub fn vadd(spec: &FpSpec, a: u32, b: u32) -> u32 {
    map2(a, b, |x, y| scalar::add16(spec, x, y))
}

/// `vfsub.{h,ah}` — lane-wise subtract.
#[inline]
pub fn vsub(spec: &FpSpec, a: u32, b: u32) -> u32 {
    map2(a, b, |x, y| scalar::sub16(spec, x, y))
}

/// `vfmul.{h,ah}` — lane-wise multiply.
#[inline]
pub fn vmul(spec: &FpSpec, a: u32, b: u32) -> u32 {
    map2(a, b, |x, y| scalar::mul16(spec, x, y))
}

/// `vfmac.{h,ah}` — lane-wise FMA with the destination as accumulator:
/// `d[i] = a[i]*b[i] + d[i]` (4 flops per instruction).
#[inline]
pub fn vmac(spec: &FpSpec, a: u32, b: u32, d: u32) -> u32 {
    let (a0, a1) = unpack2(a);
    let (b0, b1) = unpack2(b);
    let (d0, d1) = unpack2(d);
    pack2(scalar::fma16(spec, a0, b0, d0), scalar::fma16(spec, a1, b1, d1))
}

/// `vfmin.{h,ah}` — lane-wise minimumNumber.
#[inline]
pub fn vmin(spec: &FpSpec, a: u32, b: u32) -> u32 {
    map2(a, b, |x, y| scalar::min16(spec, x, y))
}

/// `vfmax.{h,ah}` — lane-wise maximumNumber.
#[inline]
pub fn vmax(spec: &FpSpec, a: u32, b: u32) -> u32 {
    map2(a, b, |x, y| scalar::max16(spec, x, y))
}

/// `vfdotpex.s.{h,ah}` — expanding dot product: `acc32 + a0*b0 + a1*b1`
/// with binary32 result. Products are exact in the wide datapath; the sum is
/// rounded once to binary32 (FPnew ExSdotp behaviour). This is the
/// "dot-product intrinsic accumulating two products" the paper's MATMUL and
/// FIR vector variants rely on (4 flops per instruction).
#[inline]
pub fn vdotp_widen(spec: &FpSpec, a: u32, b: u32, acc: u32) -> u32 {
    let (a0, a1) = unpack2(a);
    let (b0, b1) = unpack2(b);
    let p0 = spec.to_f64(a0) * spec.to_f64(b0); // exact
    let p1 = spec.to_f64(a1) * spec.to_f64(b1); // exact
    let s = f32::from_bits(acc) as f64 + p0 + p1;
    (s as f32).to_bits()
}

/// `vfeq/vflt/vfle.{h,ah}` — lane-wise compare, all-ones mask per true lane.
#[inline]
pub fn vcmp(spec: &FpSpec, a: u32, b: u32, pred: scalar::CmpPred) -> u32 {
    map2(a, b, |x, y| {
        if scalar::cmp16(spec, x, y, pred) == 1 {
            0xFFFF
        } else {
            0
        }
    })
}

/// `pv.shuffle`-style lane permute: selector 0..=3 encodes (hi_src, lo_src)
/// with bit1 choosing the half for lane1 and bit0 for lane0.
#[inline]
pub fn vshuffle(a: u32, sel: u32) -> u32 {
    let (a0, a1) = unpack2(a);
    let lo = if sel & 1 == 0 { a0 } else { a1 };
    let hi = if sel & 2 == 0 { a0 } else { a1 };
    pack2(lo, hi)
}

/// `pv.pack.lo/hi` two-register pack: takes lane0 of `a` and lane0 of `b`.
#[inline]
pub fn vpack_lo(a: u32, b: u32) -> u32 {
    pack2(unpack2(a).0, unpack2(b).0)
}

/// Takes lane1 of `a` and lane1 of `b`.
#[inline]
pub fn vpack_hi(a: u32, b: u32) -> u32 {
    pack2(unpack2(a).1, unpack2(b).1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfp::scalar::CmpPred;
    use crate::transfp::spec::{BF16, F16};

    fn pk(spec: &FpSpec, lo: f64, hi: f64) -> u32 {
        pack2(spec.from_f64(lo), spec.from_f64(hi))
    }

    fn unpk(spec: &FpSpec, v: u32) -> (f64, f64) {
        let (lo, hi) = unpack2(v);
        (spec.to_f64(lo), spec.to_f64(hi))
    }

    #[test]
    fn lane_independence() {
        let a = pk(&F16, 1.0, 1000.0);
        let b = pk(&F16, 2.0, -1000.0);
        assert_eq!(unpk(&F16, vadd(&F16, a, b)), (3.0, 0.0));
        // Lane 1 overflows f16 (−10⁶ < −65504) → −inf; lane 0 unaffected.
        let (lo, hi) = unpk(&F16, vmul(&F16, a, b));
        assert_eq!(lo, 2.0);
        assert!(hi.is_infinite() && hi < 0.0);
    }

    #[test]
    fn vmac_accumulates_per_lane() {
        let a = pk(&F16, 2.0, 3.0);
        let b = pk(&F16, 4.0, 5.0);
        let d = pk(&F16, 1.0, -1.0);
        assert_eq!(unpk(&F16, vmac(&F16, a, b, d)), (9.0, 14.0));
    }

    #[test]
    fn dotp_widening_precision() {
        // Sum that overflows f16 but not f32: the expanding dot product keeps it.
        let a = pk(&F16, 256.0, 256.0);
        let b = pk(&F16, 256.0, 256.0);
        let r = f32::from_bits(vdotp_widen(&F16, a, b, 0));
        assert_eq!(r, 131072.0); // 2*256^2 > f16 max (65504)
        // and a pure-f16 vmac would saturate:
        let m = vmac(&F16, a, b, pk(&F16, 256.0 * 256.0, 0.0));
        assert!(F16.is_inf(unpack2(m).0));
    }

    #[test]
    fn bf16_lanes() {
        let a = pk(&BF16, 1.5, 2.0e38);
        let b = pk(&BF16, 2.0, 2.0e38);
        let (lo, hi) = unpk(&BF16, vadd(&BF16, a, b));
        assert_eq!(lo, 3.5);
        assert!(hi.is_infinite());
    }

    #[test]
    fn shuffle_and_pack() {
        let a = pack2(0x1111, 0x2222);
        let b = pack2(0x3333, 0x4444);
        assert_eq!(vshuffle(a, 0b01), pack2(0x2222, 0x1111));
        assert_eq!(vshuffle(a, 0b11), pack2(0x2222, 0x2222));
        assert_eq!(vpack_lo(a, b), pack2(0x1111, 0x3333));
        assert_eq!(vpack_hi(a, b), pack2(0x2222, 0x4444));
    }

    #[test]
    fn vcmp_masks() {
        let a = pk(&F16, 1.0, 5.0);
        let b = pk(&F16, 2.0, 4.0);
        assert_eq!(vcmp(&F16, a, b, CmpPred::Lt), 0x0000FFFF);
        assert_eq!(vcmp(&F16, a, b, CmpPred::Le), 0x0000FFFF);
        assert_eq!(vcmp(&F16, a, a, CmpPred::Eq), 0xFFFFFFFF);
    }
}
