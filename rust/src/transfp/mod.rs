//! Bit-accurate transprecision floating-point substrate.
//!
//! This module reimplements, in software, the numerics of the FPnew
//! transprecision FPU integrated in the paper's cluster (§3.2): IEEE
//! binary32 scalars, IEEE binary16 (`float16`) and bfloat16 scalars, 2×16
//! packed-SIMD vectors on the 32-bit datapath, widening multi-format FMA,
//! cast-and-pack, and the iterative DIV-SQRT block's operations.
//!
//! Everything operates on raw bit patterns (`u32` registers, 16-bit lanes as
//! `u16`), because the simulated register file is format-oblivious exactly
//! like the hardware one.

pub mod cast;
pub mod scalar;
pub mod simd;
pub mod spec;

pub use scalar::CmpPred;
pub use spec::{FpSpec, BF16, F16};

/// Which FP format a (micro-)instruction operates in. `VecF16`/`VecBf16`
/// are the packed-SIMD 2×16 modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpMode {
    F32,
    F16,
    Bf16,
    VecF16,
    VecBf16,
}

impl FpMode {
    /// The 16-bit lane spec, if this mode has one.
    pub fn spec(&self) -> Option<&'static FpSpec> {
        match self {
            FpMode::F32 => None,
            FpMode::F16 | FpMode::VecF16 => Some(&F16),
            FpMode::Bf16 | FpMode::VecBf16 => Some(&BF16),
        }
    }

    /// Number of lanes (1 scalar, 2 packed).
    pub fn lanes(&self) -> u32 {
        match self {
            FpMode::VecF16 | FpMode::VecBf16 => 2,
            _ => 1,
        }
    }

    /// True for the packed-SIMD modes.
    pub fn is_vector(&self) -> bool {
        self.lanes() == 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_properties() {
        assert_eq!(FpMode::F32.lanes(), 1);
        assert_eq!(FpMode::VecF16.lanes(), 2);
        assert!(FpMode::VecBf16.is_vector());
        assert!(FpMode::F32.spec().is_none());
        assert_eq!(FpMode::VecBf16.spec().unwrap().exp_bits, 8);
        assert_eq!(FpMode::F16.spec().unwrap().man_bits, 10);
    }
}

/// Numeric edge cases the tuner's error metrics depend on: cast
/// round-trips, NaN/Inf propagation through the widening FMA, and
/// subnormal behaviour (this datapath does **not** flush subnormals —
/// FPnew's smallFloat units are IEEE-complete, and the accuracy metrics
/// assume gradual underflow).
#[cfg(test)]
mod edge_tests {
    use super::cast::{f16_to_32, f32_to_16};
    use super::scalar::{add16, fma_widen, mul16};
    use super::{BF16, F16};

    /// Every finite 16-bit value survives the 16 → f32 → 16 round trip in
    /// both formats (f32 embeds both exactly), and NaN/Inf map to NaN/Inf.
    #[test]
    fn cast_roundtrip_all_finite_both_formats() {
        for spec in [&F16, &BF16] {
            for bits in 0u16..=0xFFFF {
                let up = f16_to_32(spec, bits);
                if spec.is_nan(bits) {
                    assert!(f32::from_bits(up).is_nan());
                    assert!(spec.is_nan(f32_to_16(spec, up)));
                    continue;
                }
                if spec.is_inf(bits) {
                    assert!(f32::from_bits(up).is_infinite());
                }
                assert_eq!(
                    f32_to_16(spec, up),
                    bits,
                    "{}-bit exp roundtrip failed for {bits:#06x}",
                    spec.exp_bits
                );
            }
        }
    }

    /// Widening FMA (`fmac.s.h`): NaN and Inf inputs propagate per IEEE —
    /// NaN anywhere → NaN; Inf·finite + finite → Inf; Inf·0 → NaN;
    /// Inf + (−Inf) → NaN.
    #[test]
    fn widening_fma_nan_inf_propagation() {
        for spec in [&F16, &BF16] {
            let one = spec.from_f64(1.0);
            let zero = spec.from_f64(0.0);
            let inf = spec.inf(false);
            let ninf = spec.inf(true);
            let nan = spec.qnan();
            let acc1 = 1.0f32.to_bits();

            assert!(f32::from_bits(fma_widen(spec, nan, one, acc1)).is_nan());
            assert!(f32::from_bits(fma_widen(spec, one, nan, acc1)).is_nan());
            assert!(f32::from_bits(fma_widen(spec, one, one, f32::NAN.to_bits())).is_nan());

            let r = f32::from_bits(fma_widen(spec, inf, one, acc1));
            assert!(r.is_infinite() && r > 0.0);
            let r = f32::from_bits(fma_widen(spec, ninf, one, acc1));
            assert!(r.is_infinite() && r < 0.0);
            // The two IEEE invalid-operation cases.
            assert!(f32::from_bits(fma_widen(spec, inf, zero, acc1)).is_nan());
            assert!(f32::from_bits(fma_widen(spec, inf, one, f32::NEG_INFINITY.to_bits()))
                .is_nan());
        }
    }

    /// Subnormals are kept, not flushed: the smallest subnormal survives
    /// arithmetic identity ops, halving the smallest normal lands *in* the
    /// subnormal range, and narrowing casts produce subnormal encodings.
    #[test]
    fn subnormals_are_not_flushed() {
        for spec in [&F16, &BF16] {
            let min_sub = 1u16; // smallest positive subnormal encoding
            let one = spec.from_f64(1.0);
            // x * 1.0 and x + 0.0 keep the subnormal (no flush-to-zero).
            assert_eq!(mul16(spec, min_sub, one), min_sub);
            assert_eq!(add16(spec, min_sub, spec.from_f64(0.0)), min_sub);
            // Halving the smallest normal is subnormal, exact, non-zero.
            let min_normal = spec.pack(false, 1, 0);
            let half = spec.from_f64(0.5);
            let halved = mul16(spec, min_normal, half);
            let (_, exp, man) = spec.unpack(halved);
            assert_eq!(exp, 0, "result must be subnormal");
            assert_ne!(man, 0, "result must not flush to zero");
            assert_eq!(spec.to_f64(halved), spec.to_f64(min_normal) / 2.0);
            // Narrowing a subnormal-range f32 value yields the subnormal.
            let via_cast = f32_to_16(spec, (spec.to_f64(min_sub) as f32).to_bits());
            assert_eq!(via_cast, min_sub);
            // And the widening FMA sees the subnormal's exact value.
            let r = f32::from_bits(fma_widen(spec, min_sub, one, 0.0f32.to_bits()));
            assert_eq!(r as f64, spec.to_f64(min_sub) as f32 as f64);
        }
    }
}
