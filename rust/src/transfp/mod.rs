//! Bit-accurate transprecision floating-point substrate.
//!
//! This module reimplements, in software, the numerics of the FPnew
//! transprecision FPU integrated in the paper's cluster (§3.2): IEEE
//! binary32 scalars, IEEE binary16 (`float16`) and bfloat16 scalars, 2×16
//! packed-SIMD vectors on the 32-bit datapath, widening multi-format FMA,
//! cast-and-pack, and the iterative DIV-SQRT block's operations.
//!
//! Everything operates on raw bit patterns (`u32` registers, 16-bit lanes as
//! `u16`), because the simulated register file is format-oblivious exactly
//! like the hardware one.

pub mod cast;
pub mod scalar;
pub mod simd;
pub mod spec;

pub use scalar::CmpPred;
pub use spec::{FpSpec, BF16, F16};

/// Which FP format a (micro-)instruction operates in. `VecF16`/`VecBf16`
/// are the packed-SIMD 2×16 modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpMode {
    F32,
    F16,
    Bf16,
    VecF16,
    VecBf16,
}

impl FpMode {
    /// The 16-bit lane spec, if this mode has one.
    pub fn spec(&self) -> Option<&'static FpSpec> {
        match self {
            FpMode::F32 => None,
            FpMode::F16 | FpMode::VecF16 => Some(&F16),
            FpMode::Bf16 | FpMode::VecBf16 => Some(&BF16),
        }
    }

    /// Number of lanes (1 scalar, 2 packed).
    pub fn lanes(&self) -> u32 {
        match self {
            FpMode::VecF16 | FpMode::VecBf16 => 2,
            _ => 1,
        }
    }

    /// True for the packed-SIMD modes.
    pub fn is_vector(&self) -> bool {
        self.lanes() == 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_properties() {
        assert_eq!(FpMode::F32.lanes(), 1);
        assert_eq!(FpMode::VecF16.lanes(), 2);
        assert!(FpMode::VecBf16.is_vector());
        assert!(FpMode::F32.spec().is_none());
        assert_eq!(FpMode::VecBf16.spec().unwrap().exp_bits, 8);
        assert_eq!(FpMode::F16.spec().unwrap().man_bits, 10);
    }
}
