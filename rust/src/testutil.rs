//! Minimal in-repo property-testing + PRNG utilities.
//!
//! The build environment is fully offline (no `proptest`/`rand`), so tests
//! and workload generators use this deterministic xorshift-based kit. The
//! property harness runs a closure over N pseudo-random cases and reports
//! the failing seed for reproduction.

/// Deterministic xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seeded generator (seed 0 is remapped to a fixed odd constant).
    pub fn new(seed: u64) -> Self {
        Rng(if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed })
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.unit() * (hi - lo)
    }

    /// Uniform f32 in `[lo, hi)` — the workload generators' staple.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.range(lo as f64, hi as f64) as f32
    }

    /// A vector of uniform f32 samples.
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Standard-normal-ish sample (sum of 4 uniforms, CLT approximation) —
    /// good enough for synthetic sensor noise.
    pub fn gauss(&mut self) -> f64 {
        (0..4).map(|_| self.unit()).sum::<f64>() * (3.0f64).sqrt() - 2.0 * (3.0f64).sqrt() / 2.0
    }
}

/// Run `body` over `cases` seeded pseudo-random cases; panics with the
/// failing seed on the first failure.
pub fn check_cases(cases: u64, mut body: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Assert two f32 slices are elementwise close (absolute + relative bound).
pub fn assert_allclose(actual: &[f32], expect: &[f32], rtol: f32, atol: f32) {
    assert_eq!(actual.len(), expect.len(), "length mismatch");
    for (i, (a, e)) in actual.iter().zip(expect).enumerate() {
        let tol = atol + rtol * e.abs();
        assert!(
            (a - e).abs() <= tol || (a.is_nan() && e.is_nan()),
            "mismatch at {i}: actual={a}, expect={e}, |diff|={} > tol={tol}",
            (a - e).abs()
        );
    }
}

/// Max ulp distance between two same-format 16-bit values (diagnostics for
/// the transprecision comparisons).
pub fn ulp_dist_16(a: u16, b: u16) -> u32 {
    // Map sign-magnitude to a monotone integer line.
    let key = |x: u16| -> i32 {
        if x & 0x8000 != 0 {
            -((x & 0x7FFF) as i32)
        } else {
            (x & 0x7FFF) as i32
        }
    };
    (key(a) - key(b)).unsigned_abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_ranges() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
            let u = r.below(17);
            assert!(u < 17);
        }
    }

    #[test]
    fn check_cases_runs_all() {
        let mut n = 0;
        check_cases(25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn allclose_accepts_and_rejects() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-6);
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6);
        });
        assert!(r.is_err());
    }

    #[test]
    fn ulp_distance() {
        assert_eq!(ulp_dist_16(0x3C00, 0x3C01), 1);
        assert_eq!(ulp_dist_16(0x0000, 0x8000), 0); // ±0 are adjacent keys (both 0)
        assert_eq!(ulp_dist_16(0x3C00, 0x3C00), 0);
    }
}
