//! Differential harness: the event-driven issue engine must be
//! cycle-for-cycle identical to the per-cycle reference engine — same
//! outputs, same total cycles, same per-core counter values — across the
//! benchmark suite, both variants, a sample of the Table 2 design space,
//! partial-occupancy runs (including the solo fast path), and randomly
//! generated mixed programs. Plus the determinism guarantees the sweep
//! coordinator relies on.

use transpfp::cluster::counters::RunStats;
use transpfp::cluster::{Cluster, Engine};
use transpfp::config::ClusterConfig;
use transpfp::coordinator::sweep;
use transpfp::isa::{regs, Program, ProgramBuilder};
use transpfp::kernels::{Benchmark, Variant};
use transpfp::testutil::{check_cases, Rng};
use transpfp::transfp::FpMode;

fn assert_identical(fast: &RunStats, reference: &RunStats, ctx: &str) {
    assert_eq!(
        fast.total_cycles, reference.total_cycles,
        "{ctx}: engines disagree on total cycles"
    );
    assert_eq!(fast.per_core.len(), reference.per_core.len(), "{ctx}: core count");
    for (i, (f, r)) in fast.per_core.iter().zip(&reference.per_core).enumerate() {
        assert_eq!(f, r, "{ctx}: engines disagree on core {i} counters");
    }
}

/// The sampled configurations: corners of the design space (max sharing /
/// private FPUs, 0/1/2 pipeline stages, 8 and 16 cores).
fn sampled_configs() -> [ClusterConfig; 5] {
    [
        ClusterConfig::new(8, 2, 0),
        ClusterConfig::new(8, 4, 1),
        ClusterConfig::new(8, 8, 2),
        ClusterConfig::new(16, 8, 1),
        ClusterConfig::new(16, 16, 0),
    ]
}

/// All 8 kernels × the full 5-rung ladder (scalar, scalar-f16, scalar-bf16,
/// vector-f16, vector-bf16) × the config sample: cycle-exact.
#[test]
fn kernels_cycle_identical_across_engines() {
    for cfg in sampled_configs() {
        for b in Benchmark::all() {
            for v in Variant::all() {
                let w = b.build(v, &cfg);
                let (sf, of) = w.run_with(&cfg, cfg.cores, Engine::Event);
                let (sr, or) = w.run_with(&cfg, cfg.cores, Engine::Reference);
                let ctx = format!("{} {} on {cfg}", b.name(), v.label());
                assert_eq!(of, or, "{ctx}: outputs differ");
                assert_identical(&sf, &sr, &ctx);
                w.verify(&of).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            }
        }
    }
}

/// Partial occupancy, including the single-worker solo fast path where the
/// event engine batches memory, DIV-SQRT and barriers too.
#[test]
fn partial_occupancy_cycle_identical() {
    let cfg = ClusterConfig::new(16, 8, 1);
    for b in [Benchmark::Fir, Benchmark::Matmul, Benchmark::Kmeans, Benchmark::Fft] {
        for workers in [1usize, 3, 7, 16] {
            let w = b.build(Variant::Scalar, &cfg);
            let (sf, of) = w.run_with(&cfg, workers, Engine::Event);
            let (sr, or) = w.run_with(&cfg, workers, Engine::Reference);
            let ctx = format!("{} with {workers} workers", b.name());
            assert_eq!(of, or, "{ctx}: outputs differ");
            assert_identical(&sf, &sr, &ctx);
        }
    }
}

/// Generate a random SPMD program mixing every hazard class: hw loops,
/// branches, TCDM loads/stores (shared and per-core addresses), FP datapath
/// ops, divides, L2 traffic and barriers. Always terminates.
fn random_mixed_program(rng: &mut Rng) -> Program {
    let mut b = ProgramBuilder::new("random-mixed");
    let iters = 3 + rng.below(10) as u32;
    b.li(1, iters);
    b.li(2, (rng.next_u32() & 0xFFFF) | 1);
    b.li(3, 0);
    b.li(20, 1065353216); // 1.0f32
    b.li(21, 1073741824); // 2.0f32
    // Per-core and shared TCDM pointers.
    b.li(15, transpfp::cluster::mem::TCDM_BASE);
    b.slli(16, regs::CORE_ID, 2);
    b.add(16, 15, 16);
    b.hwloop(1);
    match rng.below(6) {
        0 => {
            b.add(3, 3, 2);
            b.xor(2, 2, 3);
        }
        1 => {
            b.fmac(FpMode::F32, 22, 20, 21);
            b.addi(3, 3, 1);
        }
        2 => {
            b.lw(4, 15, 0); // shared word: bank contention
            b.add(3, 3, 4);
        }
        3 => {
            b.sw(3, 16, 0); // private word
            b.lw(4, 16, 0);
        }
        4 => {
            b.fadd(FpMode::VecF16, 23, 20, 21);
            b.vshuffle(24, 23, 0b01);
        }
        _ => {
            b.mul(3, 3, 2);
            b.srli(2, 2, 1);
        }
    }
    b.hwloop_end();
    if rng.below(2) == 0 {
        b.barrier();
    }
    if rng.below(3) == 0 {
        b.fdiv(FpMode::F32, 25, 21, 20);
    }
    if rng.below(4) == 0 {
        b.li(17, transpfp::cluster::mem::L2_BASE);
        b.lw(18, 17, 0);
        b.add(3, 3, 18);
    }
    // Divergent control flow: odd cores skip some extra work.
    b.andi(5, regs::CORE_ID, 1);
    b.bne(5, regs::ZERO, "odd");
    b.li(6, 5 + rng.below(20) as u32);
    b.hwloop(6);
    b.addi(3, 3, 3);
    b.hwloop_end();
    b.label("odd");
    b.sw(3, 16, 0);
    b.barrier();
    b.end();
    b.build()
}

/// Random mixed programs are cycle-identical on both engines across
/// configurations with different sharing/pipeline parameters.
#[test]
fn random_programs_cycle_identical() {
    let configs = [
        ClusterConfig::new(8, 2, 1),
        ClusterConfig::new(8, 8, 0),
        ClusterConfig::new(16, 4, 2),
    ];
    check_cases(15, |rng: &mut Rng| {
        let prog = random_mixed_program(rng);
        for &cfg in &configs {
            let mut fast = Cluster::new(cfg, prog.clone());
            let mut reference = Cluster::new(cfg, prog.clone());
            let sf = fast.run_with(Engine::Event);
            let sr = reference.run_with(Engine::Reference);
            assert_identical(&sf, &sr, &format!("random program on {cfg}"));
            // Architectural state must agree too.
            for (cf, cr) in fast.cores.iter().zip(&reference.cores) {
                assert_eq!(cf.regs, cr.regs, "core {} registers", cf.id);
            }
        }
    });
}

/// Generate a random *runtime-scheduled* SPMD program: a `parallel_for`
/// with a random scheduling policy over a random trip count (0 and 1
/// included), whose body runs a small FP workload in one of the 5 ladder
/// modes and publishes per-index results to TCDM. An optional second
/// parallel section and a master/worker event handshake follow — the
/// fork-join runtime's whole surface (static chunking, TCDM atomics,
/// guided locks, software events, barriers) lands in the differential
/// wall.
fn random_runtime_program(rng: &mut Rng, cfg: &ClusterConfig) -> Program {
    use transpfp::kernels::Alloc;
    use transpfp::runtime::{parallel_for, LoopRegs, Schedule, WorkQueue};

    let mut al = Alloc::new(cfg);
    let _guard = al.words(16); // keep data away from the queues
    let q1 = WorkQueue::alloc(&mut al);
    let q2 = WorkQueue::alloc(&mut al);
    let out = al.words(40); // section 1: one word per (i % 40)
    let out2 = al.words(128); // section 2: one word per index, n2 <= 128
    let pick = |rng: &mut Rng, q: WorkQueue| match rng.below(3) {
        0 => Schedule::Static,
        1 => Schedule::Dynamic { chunk: 1 + rng.below(4) as u32, queue: q },
        _ => Schedule::Guided { min_chunk: 1 + rng.below(2) as u32, queue: q },
    };
    // Trip counts include the degenerate 0 and 1.
    let trips = [0u32, 1, 2, 7, 33, 128];
    let n = trips[rng.below(trips.len() as u64) as usize];
    let mode = [FpMode::F32, FpMode::F16, FpMode::Bf16, FpMode::VecF16, FpMode::VecBf16]
        [rng.below(5) as usize];

    let mut b = ProgramBuilder::new("random-runtime");
    b.li(LoopRegs::KERNEL.n, n);
    let sched = pick(rng, q1);
    parallel_for(
        &mut b,
        sched,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            // out[i % 40] = f(i) in the chosen mode — order-independent.
            p.fcvt_from_int(FpMode::F32, 20, 13);
            if matches!(mode, FpMode::VecF16 | FpMode::VecBf16) {
                p.cpka(mode, 20, 20, 20);
                p.fmac(mode, 20, 20, 20);
            } else if matches!(mode, FpMode::F16 | FpMode::Bf16) {
                p.fcvt_down(mode, 20, 20);
                p.fmac(mode, 20, 20, 20);
            } else {
                p.fmac(mode, 20, 20, 20);
            }
            p.li(21, 40);
            p.rem(22, 13, transpfp::isa::Operand::Reg(21));
            p.slli(22, 22, 2);
            p.li(21, out);
            p.add(21, 21, 22);
            p.sw(20, 21, 0);
        },
    );
    b.barrier();
    if rng.below(2) == 0 {
        // A second, differently-scheduled section over a different count.
        let n2 = trips[rng.below(trips.len() as u64) as usize];
        b.li(LoopRegs::KERNEL.n, n2);
        let sched2 = pick(rng, q2);
        parallel_for(
            &mut b,
            sched2,
            LoopRegs::KERNEL,
            |_| {},
            |p| {
                p.slli(22, 13, 2);
                p.li(21, out2);
                p.add(21, 21, 22);
                p.sw(13, 21, 0);
            },
        );
        b.barrier();
    }
    if rng.below(2) == 0 {
        // Master/worker event handshake.
        b.bne(regs::CORE_ID, regs::ZERO, "worker");
        b.li(1, 10 + rng.below(40) as u32);
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.hwloop_end();
        b.set_event(3);
        b.label("worker");
        b.wait_event(3);
        b.barrier();
    }
    b.end();
    b.build()
}

/// The fuzzed engine-parity wall: random runtime-scheduled programs at
/// random occupancy must be cycle-identical between the event and
/// reference engines (seed-logged by `check_cases` so failures reproduce).
#[test]
fn runtime_scheduled_programs_cycle_identical() {
    let configs = [
        ClusterConfig::new(8, 2, 0),
        ClusterConfig::new(8, 8, 1),
        ClusterConfig::new(16, 4, 2),
    ];
    check_cases(20, |rng: &mut Rng| {
        let cfg = configs[rng.below(configs.len() as u64) as usize];
        let workers = 1 + rng.below(cfg.cores as u64) as usize;
        let prog = random_runtime_program(rng, &cfg);
        let mut fast = Cluster::new(cfg, prog.clone());
        let mut reference = Cluster::new(cfg, prog);
        fast.limit_active_cores(workers);
        reference.limit_active_cores(workers);
        let sf = fast.run_with(Engine::Event);
        let sr = reference.run_with(Engine::Reference);
        assert_identical(&sf, &sr, &format!("runtime program on {cfg} with {workers} workers"));
        for (cf, cr) in fast.cores.iter().zip(&reference.cores) {
            assert_eq!(cf.regs, cr.regs, "core {} registers", cf.id);
        }
        // Architectural memory agrees too (the scheduler's work queues and
        // the published results).
        for i in 0..100u32 {
            let a = transpfp::cluster::mem::TCDM_BASE + 4 * i;
            assert_eq!(
                fast.mem.load(a, transpfp::isa::MemSize::Word),
                reference.mem.load(a, transpfp::isa::MemSize::Word),
                "TCDM word {i}"
            );
        }
    });
}

/// Two identical sweeps produce identical `Measurement` orderings and
/// cycle counts — the lock-free collection is deterministic.
#[test]
fn sweep_is_deterministic() {
    let configs = [ClusterConfig::new(8, 4, 1), ClusterConfig::new(16, 16, 2)];
    let benches = [Benchmark::Fir, Benchmark::Matmul, Benchmark::Svm];
    let variants = [Variant::Scalar, Variant::VEC];
    let key = |ms: &[transpfp::coordinator::Measurement]| -> Vec<(String, String, String, u64)> {
        ms.iter()
            .map(|m| {
                (m.cfg.mnemonic(), m.bench.name().to_string(), m.variant.label().to_string(), m.cycles)
            })
            .collect()
    };
    let a = sweep(&configs, &benches, &variants);
    let b = sweep(&configs, &benches, &variants);
    assert_eq!(a.len(), configs.len() * benches.len() * variants.len());
    assert_eq!(key(&a), key(&b), "sweep results must be deterministic");
    // Slot order is (config, bench, variant) regardless of worker timing.
    assert_eq!(a[0].bench, Benchmark::Fir);
    assert_eq!(a[1].variant.label(), "vector-f16");
    assert_eq!(a[a.len() - 1].cfg.mnemonic(), "16c16f2p");
}

/// Cluster reuse via reset() is indistinguishable from fresh construction,
/// for both engines.
#[test]
fn reset_reuse_matches_fresh_runs() {
    let cfg = ClusterConfig::new(8, 4, 1);
    for b in [Benchmark::Fir, Benchmark::Dwt] {
        let w = b.build(Variant::VEC, &cfg);
        let (fresh_stats, fresh_out) = w.run(&cfg);
        let mut cl = Cluster::new(cfg, w.program.clone());
        for rep in 0..3 {
            let (stats, out) = w.run_in(&mut cl, cfg.cores);
            assert_eq!(out, fresh_out, "{} rep {rep}: outputs drifted", b.name());
            assert_identical(&stats, &fresh_stats, &format!("{} rep {rep}", b.name()));
        }
        // Engine choice is also stable under reuse.
        let (ref_stats, _) = w.run_in_with(&mut cl, cfg.cores, Engine::Reference);
        assert_identical(&fresh_stats, &ref_stats, &format!("{} reused reference", b.name()));
    }
}
