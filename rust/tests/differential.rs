//! Differential harness: the event-driven issue engine must be
//! cycle-for-cycle identical to the per-cycle reference engine — same
//! outputs, same total cycles, same per-core counter values — across the
//! benchmark suite, both variants, a sample of the Table 2 design space,
//! partial-occupancy runs (including the solo fast path), and randomly
//! generated mixed programs. Plus the determinism guarantees the sweep
//! coordinator relies on.

use transpfp::cluster::counters::RunStats;
use transpfp::cluster::{Cluster, Engine};
use transpfp::config::ClusterConfig;
use transpfp::coordinator::sweep;
use transpfp::isa::{regs, Program, ProgramBuilder};
use transpfp::kernels::{Benchmark, Variant};
use transpfp::testutil::{check_cases, Rng};
use transpfp::transfp::FpMode;

fn assert_identical(fast: &RunStats, reference: &RunStats, ctx: &str) {
    assert_eq!(
        fast.total_cycles, reference.total_cycles,
        "{ctx}: engines disagree on total cycles"
    );
    assert_eq!(fast.per_core.len(), reference.per_core.len(), "{ctx}: core count");
    for (i, (f, r)) in fast.per_core.iter().zip(&reference.per_core).enumerate() {
        assert_eq!(f, r, "{ctx}: engines disagree on core {i} counters");
    }
}

/// The sampled configurations: corners of the design space (max sharing /
/// private FPUs, 0/1/2 pipeline stages, 8 and 16 cores).
fn sampled_configs() -> [ClusterConfig; 5] {
    [
        ClusterConfig::new(8, 2, 0),
        ClusterConfig::new(8, 4, 1),
        ClusterConfig::new(8, 8, 2),
        ClusterConfig::new(16, 8, 1),
        ClusterConfig::new(16, 16, 0),
    ]
}

/// All 8 kernels × scalar / scalar-16 / vector variants × the config
/// sample: cycle-exact.
#[test]
fn kernels_cycle_identical_across_engines() {
    for cfg in sampled_configs() {
        for b in Benchmark::all() {
            for v in [Variant::Scalar, Variant::SCALAR_F16, Variant::VEC] {
                let w = b.build(v, &cfg);
                let (sf, of) = w.run_with(&cfg, cfg.cores, Engine::Event);
                let (sr, or) = w.run_with(&cfg, cfg.cores, Engine::Reference);
                let ctx = format!("{} {} on {cfg}", b.name(), v.label());
                assert_eq!(of, or, "{ctx}: outputs differ");
                assert_identical(&sf, &sr, &ctx);
                w.verify(&of).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            }
        }
    }
}

/// Partial occupancy, including the single-worker solo fast path where the
/// event engine batches memory, DIV-SQRT and barriers too.
#[test]
fn partial_occupancy_cycle_identical() {
    let cfg = ClusterConfig::new(16, 8, 1);
    for b in [Benchmark::Fir, Benchmark::Matmul, Benchmark::Kmeans, Benchmark::Fft] {
        for workers in [1usize, 3, 7, 16] {
            let w = b.build(Variant::Scalar, &cfg);
            let (sf, of) = w.run_with(&cfg, workers, Engine::Event);
            let (sr, or) = w.run_with(&cfg, workers, Engine::Reference);
            let ctx = format!("{} with {workers} workers", b.name());
            assert_eq!(of, or, "{ctx}: outputs differ");
            assert_identical(&sf, &sr, &ctx);
        }
    }
}

/// Generate a random SPMD program mixing every hazard class: hw loops,
/// branches, TCDM loads/stores (shared and per-core addresses), FP datapath
/// ops, divides, L2 traffic and barriers. Always terminates.
fn random_mixed_program(rng: &mut Rng) -> Program {
    let mut b = ProgramBuilder::new("random-mixed");
    let iters = 3 + rng.below(10) as u32;
    b.li(1, iters);
    b.li(2, (rng.next_u32() & 0xFFFF) | 1);
    b.li(3, 0);
    b.li(20, 1065353216); // 1.0f32
    b.li(21, 1073741824); // 2.0f32
    // Per-core and shared TCDM pointers.
    b.li(15, transpfp::cluster::mem::TCDM_BASE);
    b.slli(16, regs::CORE_ID, 2);
    b.add(16, 15, 16);
    b.hwloop(1);
    match rng.below(6) {
        0 => {
            b.add(3, 3, 2);
            b.xor(2, 2, 3);
        }
        1 => {
            b.fmac(FpMode::F32, 22, 20, 21);
            b.addi(3, 3, 1);
        }
        2 => {
            b.lw(4, 15, 0); // shared word: bank contention
            b.add(3, 3, 4);
        }
        3 => {
            b.sw(3, 16, 0); // private word
            b.lw(4, 16, 0);
        }
        4 => {
            b.fadd(FpMode::VecF16, 23, 20, 21);
            b.vshuffle(24, 23, 0b01);
        }
        _ => {
            b.mul(3, 3, 2);
            b.srli(2, 2, 1);
        }
    }
    b.hwloop_end();
    if rng.below(2) == 0 {
        b.barrier();
    }
    if rng.below(3) == 0 {
        b.fdiv(FpMode::F32, 25, 21, 20);
    }
    if rng.below(4) == 0 {
        b.li(17, transpfp::cluster::mem::L2_BASE);
        b.lw(18, 17, 0);
        b.add(3, 3, 18);
    }
    // Divergent control flow: odd cores skip some extra work.
    b.andi(5, regs::CORE_ID, 1);
    b.bne(5, regs::ZERO, "odd");
    b.li(6, 5 + rng.below(20) as u32);
    b.hwloop(6);
    b.addi(3, 3, 3);
    b.hwloop_end();
    b.label("odd");
    b.sw(3, 16, 0);
    b.barrier();
    b.end();
    b.build()
}

/// Random mixed programs are cycle-identical on both engines across
/// configurations with different sharing/pipeline parameters.
#[test]
fn random_programs_cycle_identical() {
    let configs = [
        ClusterConfig::new(8, 2, 1),
        ClusterConfig::new(8, 8, 0),
        ClusterConfig::new(16, 4, 2),
    ];
    check_cases(15, |rng: &mut Rng| {
        let prog = random_mixed_program(rng);
        for &cfg in &configs {
            let mut fast = Cluster::new(cfg, prog.clone());
            let mut reference = Cluster::new(cfg, prog.clone());
            let sf = fast.run_with(Engine::Event);
            let sr = reference.run_with(Engine::Reference);
            assert_identical(&sf, &sr, &format!("random program on {cfg}"));
            // Architectural state must agree too.
            for (cf, cr) in fast.cores.iter().zip(&reference.cores) {
                assert_eq!(cf.regs, cr.regs, "core {} registers", cf.id);
            }
        }
    });
}

/// Two identical sweeps produce identical `Measurement` orderings and
/// cycle counts — the lock-free collection is deterministic.
#[test]
fn sweep_is_deterministic() {
    let configs = [ClusterConfig::new(8, 4, 1), ClusterConfig::new(16, 16, 2)];
    let benches = [Benchmark::Fir, Benchmark::Matmul, Benchmark::Svm];
    let variants = [Variant::Scalar, Variant::VEC];
    let key = |ms: &[transpfp::coordinator::Measurement]| -> Vec<(String, String, String, u64)> {
        ms.iter()
            .map(|m| {
                (m.cfg.mnemonic(), m.bench.name().to_string(), m.variant.label().to_string(), m.cycles)
            })
            .collect()
    };
    let a = sweep(&configs, &benches, &variants);
    let b = sweep(&configs, &benches, &variants);
    assert_eq!(a.len(), configs.len() * benches.len() * variants.len());
    assert_eq!(key(&a), key(&b), "sweep results must be deterministic");
    // Slot order is (config, bench, variant) regardless of worker timing.
    assert_eq!(a[0].bench, Benchmark::Fir);
    assert_eq!(a[1].variant.label(), "vector-f16");
    assert_eq!(a[a.len() - 1].cfg.mnemonic(), "16c16f2p");
}

/// Cluster reuse via reset() is indistinguishable from fresh construction,
/// for both engines.
#[test]
fn reset_reuse_matches_fresh_runs() {
    let cfg = ClusterConfig::new(8, 4, 1);
    for b in [Benchmark::Fir, Benchmark::Dwt] {
        let w = b.build(Variant::VEC, &cfg);
        let (fresh_stats, fresh_out) = w.run(&cfg);
        let mut cl = Cluster::new(cfg, w.program.clone());
        for rep in 0..3 {
            let (stats, out) = w.run_in(&mut cl, cfg.cores);
            assert_eq!(out, fresh_out, "{} rep {rep}: outputs drifted", b.name());
            assert_identical(&stats, &fresh_stats, &format!("{} rep {rep}", b.name()));
        }
        // Engine choice is also stable under reuse.
        let (ref_stats, _) = w.run_in_with(&mut cl, cfg.cores, Engine::Reference);
        assert_identical(&fresh_stats, &ref_stats, &format!("{} reused reference", b.name()));
    }
}
