//! Differential harness: the event-driven issue engine must be
//! cycle-for-cycle identical to the per-cycle reference engine — same
//! outputs, same total cycles, same per-core counter values — across the
//! benchmark suite, both variants, a sample of the Table 2 design space,
//! partial-occupancy runs (including the solo fast path), and randomly
//! generated mixed programs. The architectural tiers (functional
//! interpreter and compiled backend) join the wall through
//! `BackendKind::all()`: four-way agreement on outputs, registers, TCDM
//! and retired counts, and identical structured-error classification.
//! Plus the determinism guarantees the sweep coordinator relies on.

use transpfp::cluster::backend::BackendKind;
use transpfp::cluster::counters::RunStats;
use transpfp::cluster::{Cluster, Engine};
use transpfp::config::ClusterConfig;
use transpfp::coordinator::sweep;
use transpfp::isa::{regs, Program, ProgramBuilder};
use transpfp::kernels::{Benchmark, Variant};
use transpfp::testutil::{check_cases, Rng};
use transpfp::transfp::FpMode;

fn assert_identical(fast: &RunStats, reference: &RunStats, ctx: &str) {
    assert_eq!(
        fast.total_cycles, reference.total_cycles,
        "{ctx}: engines disagree on total cycles"
    );
    assert_eq!(fast.per_core.len(), reference.per_core.len(), "{ctx}: core count");
    for (i, (f, r)) in fast.per_core.iter().zip(&reference.per_core).enumerate() {
        assert_eq!(f, r, "{ctx}: engines disagree on core {i} counters");
    }
}

/// The sampled configurations: corners of the design space (max sharing /
/// private FPUs, 0/1/2 pipeline stages, 8 and 16 cores).
fn sampled_configs() -> [ClusterConfig; 5] {
    [
        ClusterConfig::new(8, 2, 0),
        ClusterConfig::new(8, 4, 1),
        ClusterConfig::new(8, 8, 2),
        ClusterConfig::new(16, 8, 1),
        ClusterConfig::new(16, 16, 0),
    ]
}

/// All 8 kernels × the full 5-rung ladder (scalar, scalar-f16, scalar-bf16,
/// vector-f16, vector-bf16) × the config sample: cycle-exact.
#[test]
fn kernels_cycle_identical_across_engines() {
    for cfg in sampled_configs() {
        for b in Benchmark::all() {
            for v in Variant::all() {
                let w = b.build(v, &cfg);
                let (sf, of) = w.run_with(&cfg, cfg.cores, Engine::Event).unwrap();
                let (sr, or) = w.run_with(&cfg, cfg.cores, Engine::Reference).unwrap();
                let ctx = format!("{} {} on {cfg}", b.name(), v.label());
                assert_eq!(of, or, "{ctx}: outputs differ");
                assert_identical(&sf, &sr, &ctx);
                w.verify(&of).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            }
        }
    }
}

/// Partial occupancy, including the single-worker solo fast path where the
/// event engine batches memory, DIV-SQRT and barriers too.
#[test]
fn partial_occupancy_cycle_identical() {
    let cfg = ClusterConfig::new(16, 8, 1);
    for b in [Benchmark::Fir, Benchmark::Matmul, Benchmark::Kmeans, Benchmark::Fft] {
        for workers in [1usize, 3, 7, 16] {
            let w = b.build(Variant::Scalar, &cfg);
            let (sf, of) = w.run_with(&cfg, workers, Engine::Event).unwrap();
            let (sr, or) = w.run_with(&cfg, workers, Engine::Reference).unwrap();
            let ctx = format!("{} with {workers} workers", b.name());
            assert_eq!(of, or, "{ctx}: outputs differ");
            assert_identical(&sf, &sr, &ctx);
        }
    }
}

/// Four-way architectural wall: the functional interpreter AND the
/// compiled tier must agree with BOTH cycle-accurate engines on outputs,
/// final registers, the full TCDM image and the retired-instruction count,
/// for every kernel × every rung of the 5-variant precision ladder (all
/// statically scheduled — the deterministic regime where per-core state is
/// timing-independent).
#[test]
fn kernels_architecturally_identical_across_four_backends() {
    for cfg in [ClusterConfig::new(8, 4, 1), ClusterConfig::new(16, 8, 2)] {
        for b in Benchmark::all() {
            for v in Variant::all() {
                let w = b.build(v, &cfg);
                let runs: Vec<_> = BackendKind::all()
                    .into_iter()
                    .map(|k| w.run_on_backend(&cfg, cfg.cores, k.get()).expect("kernel workloads terminate"))
                    .collect();
                let ctx = format!("{} {} on {cfg}", b.name(), v.label());
                let (ev, ev_out) = &runs[0];
                for (k, (run, out)) in BackendKind::all().into_iter().zip(&runs).skip(1) {
                    let ctx = format!("{ctx} [{:?}]", k);
                    assert_eq!(ev_out, out, "{ctx}: outputs differ");
                    assert_eq!(&ev.regs, &run.regs, "{ctx}: final registers differ");
                    assert_eq!(
                        ev.mem.tcdm_words(),
                        run.mem.tcdm_words(),
                        "{ctx}: TCDM image differs"
                    );
                    assert_eq!(ev.instrs, run.instrs, "{ctx}: retired counts differ");
                }
                w.verify(ev_out).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            }
        }
    }
}

/// The functional tier also runs the DMA double-buffered tiled pipeline —
/// master/worker event handshakes, memory-mapped DMA programming, STATUS
/// drains — to the same outputs and memory image as the event engine.
#[test]
fn tiled_pipeline_architecturally_identical_functional_vs_event() {
    let cfg = ClusterConfig::new(8, 4, 1);
    let w = Benchmark::Matmul.build_tiled(&cfg, 4).expect("tiled MATMUL");
    let (ev, ev_out) = w.run_on_backend(&cfg, cfg.cores, BackendKind::Event.get()).unwrap();
    let (fu, fu_out) = w.run_on_backend(&cfg, cfg.cores, BackendKind::Functional.get()).unwrap();
    assert_eq!(ev_out, fu_out, "tiled outputs differ");
    assert_eq!(ev.regs, fu.regs, "tiled registers differ");
    assert_eq!(ev.mem.tcdm_words(), fu.mem.tcdm_words(), "tiled TCDM differs");
    w.verify(&fu_out).unwrap();
    // Event vs reference cycle parity for the tiled pipeline is covered by
    // the engine differential above; functional-vs-event suffices here.
}

/// Generate a random SPMD program mixing every hazard class: hw loops,
/// branches, TCDM loads/stores (shared and per-core addresses), FP datapath
/// ops, divides, L2 traffic and barriers. Always terminates.
fn random_mixed_program(rng: &mut Rng) -> Program {
    let mut b = ProgramBuilder::new("random-mixed");
    let iters = 3 + rng.below(10) as u32;
    b.li(1, iters);
    b.li(2, (rng.next_u32() & 0xFFFF) | 1);
    b.li(3, 0);
    b.li(20, 1065353216); // 1.0f32
    b.li(21, 1073741824); // 2.0f32
    // Per-core and shared TCDM pointers.
    b.li(15, transpfp::cluster::mem::TCDM_BASE);
    b.slli(16, regs::CORE_ID, 2);
    b.add(16, 15, 16);
    b.hwloop(1);
    match rng.below(6) {
        0 => {
            b.add(3, 3, 2);
            b.xor(2, 2, 3);
        }
        1 => {
            b.fmac(FpMode::F32, 22, 20, 21);
            b.addi(3, 3, 1);
        }
        2 => {
            b.lw(4, 15, 0); // shared word: bank contention
            b.add(3, 3, 4);
        }
        3 => {
            b.sw(3, 16, 0); // private word
            b.lw(4, 16, 0);
        }
        4 => {
            b.fadd(FpMode::VecF16, 23, 20, 21);
            b.vshuffle(24, 23, 0b01);
        }
        _ => {
            b.mul(3, 3, 2);
            b.srli(2, 2, 1);
        }
    }
    b.hwloop_end();
    if rng.below(2) == 0 {
        b.barrier();
    }
    if rng.below(3) == 0 {
        b.fdiv(FpMode::F32, 25, 21, 20);
    }
    if rng.below(4) == 0 {
        b.li(17, transpfp::cluster::mem::L2_BASE);
        b.lw(18, 17, 0);
        b.add(3, 3, 18);
    }
    // Divergent control flow: odd cores skip some extra work.
    b.andi(5, regs::CORE_ID, 1);
    b.bne(5, regs::ZERO, "odd");
    b.li(6, 5 + rng.below(20) as u32);
    b.hwloop(6);
    b.addi(3, 3, 3);
    b.hwloop_end();
    b.label("odd");
    b.sw(3, 16, 0);
    b.barrier();
    b.end();
    b.build()
}

/// Random mixed programs are cycle-identical on both engines across
/// configurations with different sharing/pipeline parameters.
#[test]
fn random_programs_cycle_identical() {
    let configs = [
        ClusterConfig::new(8, 2, 1),
        ClusterConfig::new(8, 8, 0),
        ClusterConfig::new(16, 4, 2),
    ];
    check_cases(15, |rng: &mut Rng| {
        let prog = random_mixed_program(rng);
        for &cfg in &configs {
            let mut fast = Cluster::new(cfg, prog.clone());
            let mut reference = Cluster::new(cfg, prog.clone());
            let sf = fast.run_with(Engine::Event).unwrap();
            let sr = reference.run_with(Engine::Reference).unwrap();
            assert_identical(&sf, &sr, &format!("random program on {cfg}"));
            // Architectural state must agree too.
            for (cf, cr) in fast.cores.iter().zip(&reference.cores) {
                assert_eq!(cf.regs, cr.regs, "core {} registers", cf.id);
            }
        }
    });
}

/// Generate a random *runtime-scheduled* SPMD program: a `parallel_for`
/// with a random scheduling policy over a random trip count (0 and 1
/// included), whose body runs a small FP workload in one of the 5 ladder
/// modes and publishes per-index results to TCDM. An optional second
/// parallel section and a master/worker event handshake follow — the
/// fork-join runtime's whole surface (static chunking, TCDM atomics,
/// guided locks, software events, barriers) lands in the differential
/// wall. The second return is `true` when every section is statically
/// scheduled (the regime where final registers are timing-independent and
/// the three-way wall may compare them).
fn random_runtime_program(rng: &mut Rng, cfg: &ClusterConfig) -> (Program, bool) {
    use transpfp::kernels::Alloc;
    use transpfp::runtime::{parallel_for, LoopRegs, Schedule, WorkQueue};

    let mut al = Alloc::new(cfg);
    let _guard = al.words(16); // keep data away from the queues
    let q1 = WorkQueue::alloc(&mut al);
    let q2 = WorkQueue::alloc(&mut al);
    let out = al.words(40); // section 1: one word per (i % 40)
    let out2 = al.words(128); // section 2: one word per index, n2 <= 128
    let mut all_static = true;
    let pick = |rng: &mut Rng, q: WorkQueue, all_static: &mut bool| match rng.below(3) {
        0 => Schedule::Static,
        1 => {
            *all_static = false;
            Schedule::Dynamic { chunk: 1 + rng.below(4) as u32, queue: q }
        }
        _ => {
            *all_static = false;
            Schedule::Guided { min_chunk: 1 + rng.below(2) as u32, queue: q }
        }
    };
    // Trip counts include the degenerate 0 and 1.
    let trips = [0u32, 1, 2, 7, 33, 128];
    let n = trips[rng.below(trips.len() as u64) as usize];
    let mode = [FpMode::F32, FpMode::F16, FpMode::Bf16, FpMode::VecF16, FpMode::VecBf16]
        [rng.below(5) as usize];

    let mut b = ProgramBuilder::new("random-runtime");
    b.li(LoopRegs::KERNEL.n, n);
    let sched = pick(rng, q1, &mut all_static);
    parallel_for(
        &mut b,
        sched,
        LoopRegs::KERNEL,
        |_| {},
        |p| {
            // out[i % 40] = f(i) in the chosen mode — order-independent.
            p.fcvt_from_int(FpMode::F32, 20, 13);
            if matches!(mode, FpMode::VecF16 | FpMode::VecBf16) {
                p.cpka(mode, 20, 20, 20);
                p.fmac(mode, 20, 20, 20);
            } else if matches!(mode, FpMode::F16 | FpMode::Bf16) {
                p.fcvt_down(mode, 20, 20);
                p.fmac(mode, 20, 20, 20);
            } else {
                p.fmac(mode, 20, 20, 20);
            }
            p.li(21, 40);
            p.rem(22, 13, transpfp::isa::Operand::Reg(21));
            p.slli(22, 22, 2);
            p.li(21, out);
            p.add(21, 21, 22);
            p.sw(20, 21, 0);
        },
    );
    b.barrier();
    if rng.below(2) == 0 {
        // A second, differently-scheduled section over a different count.
        let n2 = trips[rng.below(trips.len() as u64) as usize];
        b.li(LoopRegs::KERNEL.n, n2);
        let sched2 = pick(rng, q2, &mut all_static);
        parallel_for(
            &mut b,
            sched2,
            LoopRegs::KERNEL,
            |_| {},
            |p| {
                p.slli(22, 13, 2);
                p.li(21, out2);
                p.add(21, 21, 22);
                p.sw(13, 21, 0);
            },
        );
        b.barrier();
    }
    if rng.below(2) == 0 {
        // Master/worker event handshake.
        b.bne(regs::CORE_ID, regs::ZERO, "worker");
        b.li(1, 10 + rng.below(40) as u32);
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.hwloop_end();
        b.set_event(3);
        b.label("worker");
        b.wait_event(3);
        b.barrier();
    }
    b.end();
    (b.build(), all_static)
}

/// The fuzzed engine-parity wall: random runtime-scheduled programs at
/// random occupancy must be cycle-identical between the event and
/// reference engines (seed-logged by `check_cases` so failures reproduce).
#[test]
fn runtime_scheduled_programs_cycle_identical() {
    let configs = [
        ClusterConfig::new(8, 2, 0),
        ClusterConfig::new(8, 8, 1),
        ClusterConfig::new(16, 4, 2),
    ];
    check_cases(20, |rng: &mut Rng| {
        let cfg = configs[rng.below(configs.len() as u64) as usize];
        let workers = 1 + rng.below(cfg.cores as u64) as usize;
        let (prog, _) = random_runtime_program(rng, &cfg);
        let mut fast = Cluster::new(cfg, prog.clone());
        let mut reference = Cluster::new(cfg, prog);
        fast.limit_active_cores(workers);
        reference.limit_active_cores(workers);
        let sf = fast.run_with(Engine::Event).unwrap();
        let sr = reference.run_with(Engine::Reference).unwrap();
        assert_identical(&sf, &sr, &format!("runtime program on {cfg} with {workers} workers"));
        for (cf, cr) in fast.cores.iter().zip(&reference.cores) {
            assert_eq!(cf.regs, cr.regs, "core {} registers", cf.id);
        }
        // Architectural memory agrees too (the scheduler's work queues and
        // the published results).
        for i in 0..100u32 {
            let a = transpfp::cluster::mem::TCDM_BASE + 4 * i;
            assert_eq!(
                fast.mem.load(a, transpfp::isa::MemSize::Word),
                reference.mem.load(a, transpfp::isa::MemSize::Word),
                "TCDM word {i}"
            );
        }
    });
}

/// Four-way wall over the seed-logged random runtime-scheduled programs:
/// the architectural tiers must agree with both cycle-accurate engines on
/// every memory location with a unique or deterministic writer — the
/// work-queue words (the grab sequence is value-determined, not
/// timing-determined) and the per-index output array. For the statically
/// scheduled draws (chunk assignment is occupancy-determined, so per-core
/// state is timing-independent) final registers and retired-instruction
/// counts must match too. Only the `out[i % 40]` aliased-slot region is
/// exempt: several cores race the same slot by design, and the winner is
/// backend timing.
#[test]
fn runtime_scheduled_programs_architecturally_identical_across_backends() {
    let configs = [
        ClusterConfig::new(8, 2, 0),
        ClusterConfig::new(8, 8, 1),
        ClusterConfig::new(16, 4, 2),
    ];
    // Allocation layout of `random_runtime_program`, in TCDM word indices:
    // 0..16 guard, 16..20 work queues, 20..60 aliased out[i % 40],
    // 60..188 per-index out2.
    const QUEUES: std::ops::Range<u32> = 16..20;
    const OUT2: std::ops::Range<u32> = 60..188;
    check_cases(20, |rng: &mut Rng| {
        let cfg = configs[rng.below(configs.len() as u64) as usize];
        let workers = 1 + rng.below(cfg.cores as u64) as usize;
        let (prog, all_static) = random_runtime_program(rng, &cfg);
        let w_runs: Vec<_> = BackendKind::all()
            .into_iter()
            .map(|k| k.run_program(&cfg, &prog, workers, &mut |_| {}).expect("runtime programs terminate"))
            .collect();
        let ev = &w_runs[0];
        for (k, run) in BackendKind::all().into_iter().zip(&w_runs).skip(1) {
            let ctx = format!("runtime program on {cfg}, {workers} workers [{k:?}]");
            let word = |r: &transpfp::cluster::BackendRun, i: u32| {
                r.mem.load(
                    transpfp::cluster::mem::TCDM_BASE + 4 * i,
                    transpfp::isa::MemSize::Word,
                )
            };
            for i in QUEUES.chain(OUT2) {
                assert_eq!(word(ev, i), word(run, i), "{ctx}: TCDM word {i}");
            }
            // Solo runs are sequential on every backend; static schedules
            // pin each index to a core — both make registers deterministic.
            if all_static || workers == 1 {
                assert_eq!(ev.regs, run.regs, "{ctx}: final registers differ");
                assert_eq!(ev.instrs, run.instrs, "{ctx}: retired counts differ");
            }
        }
    });
}

/// Two identical sweeps produce identical `Measurement` orderings and
/// cycle counts — the lock-free collection is deterministic.
#[test]
fn sweep_is_deterministic() {
    let configs = [ClusterConfig::new(8, 4, 1), ClusterConfig::new(16, 16, 2)];
    let benches = [Benchmark::Fir, Benchmark::Matmul, Benchmark::Svm];
    let variants = [Variant::Scalar, Variant::VEC];
    let key = |ms: &[transpfp::coordinator::Measurement]| -> Vec<(String, String, String, u64)> {
        ms.iter()
            .map(|m| {
                (m.cfg.mnemonic(), m.bench.name().to_string(), m.variant.label().to_string(), m.cycles)
            })
            .collect()
    };
    let a = sweep(&configs, &benches, &variants).unwrap();
    let b = sweep(&configs, &benches, &variants).unwrap();
    assert_eq!(a.len(), configs.len() * benches.len() * variants.len());
    assert_eq!(key(&a), key(&b), "sweep results must be deterministic");
    // Slot order is (config, bench, variant) regardless of worker timing.
    assert_eq!(a[0].bench, Benchmark::Fir);
    assert_eq!(a[1].variant.label(), "vector-f16");
    assert_eq!(a[a.len() - 1].cfg.mnemonic(), "16c16f2p");
}

/// Cluster reuse via reset() is indistinguishable from fresh construction,
/// for both engines.
#[test]
fn reset_reuse_matches_fresh_runs() {
    let cfg = ClusterConfig::new(8, 4, 1);
    for b in [Benchmark::Fir, Benchmark::Dwt] {
        let w = b.build(Variant::VEC, &cfg);
        let (fresh_stats, fresh_out) = w.run(&cfg).unwrap();
        let mut cl = Cluster::new(cfg, w.program.clone());
        for rep in 0..3 {
            let (stats, out) = w.run_in(&mut cl, cfg.cores).unwrap();
            assert_eq!(out, fresh_out, "{} rep {rep}: outputs drifted", b.name());
            assert_identical(&stats, &fresh_stats, &format!("{} rep {rep}", b.name()));
        }
        // Engine choice is also stable under reuse.
        let (ref_stats, _) = w.run_in_with(&mut cl, cfg.cores, Engine::Reference).unwrap();
        assert_identical(&fresh_stats, &ref_stats, &format!("{} reused reference", b.name()));
    }
}

// ---------------------------------------------------------------- traces

/// Bit-identical trace streams: both timed engines must emit the same
/// records — same cycles, pcs, kinds and args after the canonical per-core
/// sort — with rings sized so nothing drops. Covers plain kernels across
/// the ladder on two configs plus the DMA double-buffered tiled pipeline.
#[test]
fn trace_streams_bit_identical_across_engines() {
    use transpfp::trace::TraceConfig;
    let big = TraceConfig { ring_capacity: 1 << 21 };
    let pairs = [
        (Benchmark::Fir, Variant::Scalar),
        (Benchmark::Matmul, Variant::VEC),
        (Benchmark::Conv, Variant::SCALAR_BF16),
        (Benchmark::Fft, Variant::Scalar),
        (Benchmark::Kmeans, Variant::VEC),
    ];
    for cfg in [ClusterConfig::new(8, 4, 1), ClusterConfig::new(16, 8, 2)] {
        for (b, v) in pairs {
            let w = b.build(v, &cfg);
            let (se, oe, te) = w.run_traced(&cfg, cfg.cores, Engine::Event, big).unwrap();
            let (sr, or, tr) = w.run_traced(&cfg, cfg.cores, Engine::Reference, big).unwrap();
            let ctx = format!("{} {} on {cfg}", b.name(), v.label());
            assert_eq!(oe, or, "{ctx}: outputs differ");
            assert_identical(&se, &sr, &ctx);
            assert_eq!(te.db().total_dropped(), 0, "{ctx}: event ring dropped records");
            assert_eq!(tr.db().total_dropped(), 0, "{ctx}: reference ring dropped records");
            for ci in 0..cfg.cores {
                assert_eq!(
                    te.db().sorted(ci),
                    tr.db().sorted(ci),
                    "{ctx}: core {ci} trace streams differ"
                );
            }
        }
    }
    let cfg = ClusterConfig::new(8, 4, 1);
    let w = Benchmark::Matmul.build_tiled(&cfg, 4).expect("tiled MATMUL");
    let (se, _, te) = w.run_traced(&cfg, cfg.cores, Engine::Event, big).unwrap();
    let (sr, _, tr) = w.run_traced(&cfg, cfg.cores, Engine::Reference, big).unwrap();
    assert_identical(&se, &sr, "tiled MATMUL");
    assert_eq!(te.db().total_dropped() + tr.db().total_dropped(), 0, "tiled rings dropped");
    for ci in 0..cfg.cores {
        assert_eq!(
            te.db().sorted(ci),
            tr.db().sorted(ci),
            "tiled MATMUL: core {ci} trace streams differ"
        );
    }
}

/// Tracing must be invisible to the simulation: a traced run and an
/// untraced run of the same workload report identical outputs and
/// identical per-core counters, on both engines.
#[test]
fn tracing_does_not_perturb_run_stats() {
    use transpfp::trace::TraceConfig;
    let cfg = ClusterConfig::new(8, 8, 2);
    for b in [Benchmark::Matmul, Benchmark::Fft, Benchmark::Svm] {
        for engine in [Engine::Event, Engine::Reference] {
            let w = b.build(Variant::VEC, &cfg);
            let (plain, plain_out) = w.run_with(&cfg, cfg.cores, engine).unwrap();
            let (traced, traced_out, _tracer) =
                w.run_traced(&cfg, cfg.cores, engine, TraceConfig::default()).unwrap();
            let ctx = format!("{} [{engine:?}]", b.name());
            assert_eq!(traced_out, plain_out, "{ctx}: tracing changed the outputs");
            assert_identical(&traced, &plain, &ctx);
        }
    }
}

// ---------------------------------------------------------------- errors

/// Error-path parity wall: a program that spins forever must classify as a
/// `timeout` on every execution tier — the timed engines trip the watchdog's
/// cycle budget, the functional interpreter its instruction budget. The
/// budgets differ in unit, so parity is asserted on [`RunError::class`],
/// exactly the label the fault campaigns and the coordinator report.
#[test]
fn infinite_loop_times_out_identically_across_backends() {
    use transpfp::cluster::{RunError, Watchdog};
    let mut b = ProgramBuilder::new("spin-forever");
    b.li(1, 1);
    b.label("spin");
    b.bne(1, regs::ZERO, "spin");
    b.end();
    let prog = b.build();
    let cfg = ClusterConfig::new(8, 4, 1);
    let wd = Watchdog::with_budget(50_000);
    let mut classes = Vec::new();
    for k in BackendKind::all() {
        let err = k
            .run_watched(&cfg, &prog, cfg.cores, &mut |_| {}, wd)
            .expect_err("an infinite loop must not complete on any tier");
        assert!(
            matches!(err, RunError::Timeout { budget: 50_000 }),
            "[{k:?}] expected the configured budget in the error, got {err:?}"
        );
        classes.push((k, err.class()));
    }
    for (k, class) in &classes {
        assert_eq!(*class, "timeout", "[{k:?}] wrong class");
    }
}

/// A software event line nobody raises is an *exact* `Deadlock` on every
/// tier: same variant, same count of parked cores — the error itself is
/// architectural state, so the four-way wall compares it bit-for-bit,
/// in both full- and partial-occupancy teams.
#[test]
fn never_signaled_wait_event_deadlocks_identically_across_backends() {
    use transpfp::cluster::{RunError, Watchdog};
    let mut b = ProgramBuilder::new("never-signaled");
    b.bne(regs::CORE_ID, regs::ZERO, "worker");
    b.end();
    b.label("worker");
    b.wait_event(5);
    b.end();
    let prog = b.build();
    let cfg = ClusterConfig::new(8, 4, 1);
    for workers in [8usize, 3] {
        let expected = RunError::Deadlock { asleep: workers - 1 };
        for k in BackendKind::all() {
            let err = k
                .run_watched(&cfg, &prog, workers, &mut |_| {}, Watchdog::with_budget(100_000))
                .expect_err("parked workers can never be woken");
            assert_eq!(err, expected, "[{k:?}] with {workers} workers");
            assert_eq!(err.class(), "deadlock");
        }
    }
}

/// Loop-trace edge cases across the full four-way wall: trip counts 0, 1
/// and the 16-bit maximum, nested hw loops, and a side-exit mid-iteration
/// all give identical registers, TCDM images and retired-instruction
/// counts on every tier — the compiled tier's whole-iteration dispatch
/// (and its bail-outs) must be architecturally invisible. CI runs this in
/// debug and release.
#[test]
fn loop_trace_edge_cases_identical_across_backends() {
    let counted = |n: u32| {
        let mut b = ProgramBuilder::new("trip");
        b.li(1, n);
        b.hwloop(1);
        b.addi(2, 2, 1);
        b.addi(3, 3, 2);
        b.hwloop_end();
        b.addi(4, 4, 7);
        b.end();
        b.build()
    };
    let nested = || {
        let mut b = ProgramBuilder::new("nested");
        b.li(1, 3);
        b.li(2, 4);
        b.hwloop(1);
        b.hwloop(2);
        b.addi(3, 3, 1);
        b.hwloop_end();
        b.addi(4, 4, 1);
        b.hwloop_end();
        b.end();
        b.build()
    };
    let side_exit = || {
        let mut b = ProgramBuilder::new("side-exit");
        b.li(1, 0);
        b.li(2, 57);
        b.label("loop");
        b.addi(1, 1, 1);
        b.beq(1, 2, "out");
        b.bne(1, regs::ZERO, "loop");
        b.label("out");
        b.addi(3, 3, 9);
        b.end();
        b.build()
    };
    let mut progs: Vec<(String, Program)> = Vec::new();
    for n in [0u32, 1, 65_535] {
        progs.push((format!("trip-{n}"), counted(n)));
    }
    progs.push(("nested".to_string(), nested()));
    progs.push(("side-exit".to_string(), side_exit()));
    let cfg = ClusterConfig::new(8, 4, 1);
    for (name, prog) in &progs {
        for workers in [1usize, cfg.cores] {
            let runs: Vec<_> = BackendKind::all()
                .into_iter()
                .map(|k| {
                    k.run_program(&cfg, prog, workers, &mut |_| {})
                        .expect("edge-case loops terminate")
                })
                .collect();
            let ev = &runs[0];
            for (k, run) in BackendKind::all().into_iter().zip(&runs).skip(1) {
                let ctx = format!("{name}, {workers} workers [{k:?}]");
                assert_eq!(ev.regs, run.regs, "{ctx}: final registers differ");
                assert_eq!(ev.instrs, run.instrs, "{ctx}: retired counts differ");
                assert_eq!(ev.mem.tcdm_words(), run.mem.tcdm_words(), "{ctx}: TCDM differs");
            }
        }
    }
}

/// Armed-fault interaction: corruption staged architecturally into TCDM —
/// the same word every tier's fault campaigns flip — must stay invisible
/// to the differential wall. A benign poisoned word flows through a traced
/// loop to identical results; a poisoned *pointer* that redirects an
/// atomic outside TCDM classifies as the identical structured `Fault` on
/// every tier.
#[test]
fn staged_tcdm_corruption_identical_across_backends() {
    use transpfp::cluster::mem::{L2_BASE, TCDM_BASE};
    use transpfp::isa::MemSize;

    // Benign: the poisoned word is read-modify-written inside a traced
    // hw-loop body (load + alu + store — all trace-admissible).
    let mut b = ProgramBuilder::new("poisoned-data");
    b.li(15, TCDM_BASE);
    b.li(1, 4);
    b.hwloop(1);
    b.lw(2, 15, 0);
    b.addi(2, 2, 1);
    b.sw(2, 15, 0);
    b.hwloop_end();
    b.end();
    let benign = b.build();
    let cfg = ClusterConfig::new(8, 4, 1);
    let runs: Vec<_> = BackendKind::all()
        .into_iter()
        .map(|k| {
            k.run_program(&cfg, &benign, 1, &mut |mem| {
                mem.store(TCDM_BASE, MemSize::Word, 0xDEAD_BEEF)
            })
            .expect("the benign corruption terminates")
        })
        .collect();
    let ev = &runs[0];
    assert_eq!(
        ev.mem.load(TCDM_BASE, MemSize::Word),
        0xDEAD_BEEFu32.wrapping_add(4),
        "the poisoned word was incremented once per iteration"
    );
    for (k, run) in BackendKind::all().into_iter().zip(&runs).skip(1) {
        assert_eq!(ev.regs, run.regs, "[{k:?}]: registers differ");
        assert_eq!(ev.instrs, run.instrs, "[{k:?}]: retired counts differ");
        assert_eq!(ev.mem.tcdm_words(), run.mem.tcdm_words(), "[{k:?}]: TCDM differs");
    }

    // Malign: the corrupted word is used as an atomic's base address and
    // points into L2 — a detectable violation on every tier.
    let mut b = ProgramBuilder::new("poisoned-ptr");
    b.li(15, TCDM_BASE);
    b.lw(1, 15, 0);
    b.li(2, 1);
    b.amo_add(3, 1, 0, 2);
    b.end();
    let malign = b.build();
    let errs: Vec<_> = BackendKind::all()
        .into_iter()
        .map(|k| {
            k.run_program(&cfg, &malign, 1, &mut |mem| {
                mem.store(TCDM_BASE, MemSize::Word, L2_BASE)
            })
            .expect_err("an atomic outside TCDM must fault on every tier")
        })
        .collect();
    for (k, err) in BackendKind::all().into_iter().zip(&errs) {
        assert_eq!(err.class(), "fault", "[{k:?}]: wrong class");
        assert_eq!(err, &errs[0], "[{k:?}]: fault errors must be bit-identical");
    }
}

/// The classification is build-profile independent: the same fixtures give
/// the same structured errors whether the crate is compiled with debug
/// assertions or optimized (CI runs this file under both profiles).
#[test]
fn error_classes_do_not_depend_on_debug_assertions() {
    use transpfp::cluster::{RunError, Watchdog};
    // One hang + one deadlock fixture, checked for stable classes; the
    // assert is intentionally profile-agnostic (no cfg!(debug_assertions)
    // branches) — running this test in both CI profiles is the guarantee.
    let mut spin = ProgramBuilder::new("spin-profile");
    spin.li(1, 1);
    spin.label("s");
    spin.bne(1, regs::ZERO, "s");
    spin.end();
    let spin = spin.build();
    let mut dead = ProgramBuilder::new("dead-profile");
    dead.wait_event(7);
    dead.end();
    let dead = dead.build();
    let cfg = ClusterConfig::new(8, 2, 0);
    for k in BackendKind::all() {
        let t = k
            .run_watched(&cfg, &spin, cfg.cores, &mut |_| {}, Watchdog::with_budget(20_000))
            .expect_err("spin");
        assert_eq!(t.class(), "timeout", "[{k:?}]");
        let d = k
            .run_watched(&cfg, &dead, cfg.cores, &mut |_| {}, Watchdog::with_budget(20_000))
            .expect_err("dead");
        assert_eq!(d, RunError::Deadlock { asleep: cfg.cores }, "[{k:?}]");
    }
}
