//! Fault-injection campaign walls: the simulation hot path survives SEUs,
//! hangs, and worker panics with zero lost points, and campaigns are
//! bit-deterministic in their seed and independent of the worker count.

use transpfp::cluster::{ArmedFault, Cluster, FaultSite};
use transpfp::config::ClusterConfig;
use transpfp::coordinator;
use transpfp::faults::{run_campaign, CampaignSpec, Outcome, RecoveryPolicy, SiteClass};
use transpfp::isa::{regs, ProgramBuilder};
use transpfp::kernels::{Benchmark, Variant};

/// The headline robustness gate: a fuzzed campaign of 200+ injected points
/// over the full benchmark suite (TCDM, register-file and DMA upsets alike)
/// completes with **every** point classified into the five-way taxonomy and
/// **zero** points lost — no injected run may panic the process or stall
/// the sweep, whatever the upset does to the simulated cluster.
#[test]
fn fuzzed_campaign_of_200_points_loses_nothing() {
    let mut spec = CampaignSpec::new(ClusterConfig::new(8, 8, 1));
    spec.seed = 0xF00D;
    spec.points_per_target = 13; // 13 × 8 benchmarks × 2 variants = 208
    spec.recovery = Some(RecoveryPolicy::default());
    let report = run_campaign(&spec).expect("fault-free baselines run clean");

    assert_eq!(report.points.len(), 208, "no sampled point may be lost");
    for (i, p) in report.points.iter().enumerate() {
        assert_eq!(p.index, i, "points stay in sampling order");
        assert!(Outcome::all().contains(&p.outcome), "point {i} unclassified");
        match p.outcome {
            // Detected outcomes carry the structured error and, with
            // recovery on, consumed at least one retry.
            Outcome::Crash | Outcome::Hang => {
                assert!(!p.detail.is_empty(), "point {i}: detected outcome without detail");
                // Quarantined worker panics bypass recovery (the worker is
                // gone); every other detected outcome consumed a retry.
                assert!(
                    p.attempts >= 1 || p.detail.starts_with("worker panicked"),
                    "point {i}: recovery never ran on a detected outcome"
                );
            }
            Outcome::Masked => assert!(p.detail.is_empty()),
            // Divergent-but-completed runs carry the quantified error.
            Outcome::Tolerable | Outcome::Sdc => {
                assert!(p.detail.starts_with("rel="), "point {i}: missing error detail")
            }
        }
        // SEUs are transient: recovery can only be claimed on detectable
        // outcomes, and undetectable ones never consume retries.
        if p.recovered {
            assert!(p.outcome.is_detectable(), "point {i}: recovered an undetectable outcome");
        }
    }
    // The class totals partition the campaign.
    assert_eq!(report.counts().iter().sum::<usize>(), report.points.len());
    // One CSV row per point, plus the header.
    assert_eq!(report.to_csv().lines().count(), 209);
    // Something actually happened: a 208-point campaign over three site
    // classes never comes back all-masked.
    assert!(report.counts()[0] < 208, "campaign produced no observable upsets");
}

/// Forced hang through the injection seam: flipping the sign bit of a loop
/// counter register turns a 4-iteration loop into a ~2^31-iteration one,
/// and the watchdog classifies the run on the hang path instead of
/// spinning — the exact mechanism campaign points rely on.
#[test]
fn forced_register_hang_is_a_structured_timeout() {
    let mut b = ProgramBuilder::new("loop-counter-upset");
    b.li(1, 4);
    b.label("loop");
    b.addi(1, 1, -1);
    b.bne(1, regs::ZERO, "loop");
    b.barrier();
    b.end();
    let mut cl = Cluster::new(ClusterConfig::new(8, 4, 1), b.build());
    cl.max_cycles = 50_000;
    cl.arm_fault(ArmedFault {
        cycle: 2,
        site: FaultSite::RegCell { core: 0, reg: 1, bit: 31 },
    });
    let err = cl.run().expect_err("the flipped counter must outlive the watchdog");
    assert_eq!(err.class(), "timeout", "hang-class detection, got {err:?}");
}

/// Same seed, same flags — bit-identical outcome CSV whether the campaign
/// runs on one worker or many (`--jobs 1` vs `--jobs N`): sampling happens
/// serially up front and classification is a pure function of the point.
#[test]
fn campaign_csv_is_identical_across_worker_counts() {
    let mut spec = CampaignSpec::new(ClusterConfig::new(8, 4, 1));
    spec.seed = 7;
    spec.points_per_target = 4;
    spec.benches = vec![Benchmark::Fir, Benchmark::Dwt];
    spec.variants = vec![Variant::Scalar, Variant::VEC];
    let prev = coordinator::max_jobs();
    coordinator::set_max_jobs(1);
    let serial = run_campaign(&spec).expect("baselines run").to_csv();
    coordinator::set_max_jobs(8);
    let parallel = run_campaign(&spec).expect("baselines run").to_csv();
    coordinator::set_max_jobs(prev);
    assert_eq!(serial, parallel, "--jobs must not change campaign outcomes");
    assert_eq!(serial.lines().count(), 17, "header + 4 points × 4 targets");
}

/// Site-class filtering is honored: a TCDM-only campaign samples TCDM
/// sites exclusively, and the CSV encodes each site unambiguously.
#[test]
fn site_filter_restricts_the_sampled_sites() {
    let mut spec = CampaignSpec::new(ClusterConfig::new(8, 4, 1));
    spec.seed = 11;
    spec.points_per_target = 6;
    spec.sites = vec![SiteClass::Tcdm];
    spec.benches = vec![Benchmark::Fir];
    spec.variants = vec![Variant::Scalar];
    let report = run_campaign(&spec).expect("baselines run");
    assert_eq!(report.points.len(), 6);
    for p in &report.points {
        assert!(
            matches!(p.fault.site, FaultSite::TcdmWord { .. }),
            "non-TCDM site in a TCDM-only campaign: {:?}",
            p.fault.site
        );
    }
    for line in report.to_csv().lines().skip(1) {
        assert!(line.contains(",tcdm:"), "CSV row lost its site encoding: {line}");
    }
}

/// Recovery semantics at campaign level: with recovery disabled no point
/// reports attempts; the classification itself is unchanged (recovery
/// re-runs fault-free, it can never relabel the original outcome).
#[test]
fn disabling_recovery_changes_attempts_not_outcomes() {
    let mut spec = CampaignSpec::new(ClusterConfig::new(8, 4, 1));
    spec.seed = 23;
    spec.points_per_target = 8;
    spec.benches = vec![Benchmark::Matmul];
    spec.variants = vec![Variant::Scalar];
    let with = run_campaign(&spec).expect("baselines run");
    spec.recovery = None;
    let without = run_campaign(&spec).expect("baselines run");
    assert_eq!(with.points.len(), without.points.len());
    for (a, b) in with.points.iter().zip(&without.points) {
        assert_eq!(a.outcome, b.outcome, "point {}: recovery relabeled an outcome", a.index);
        assert_eq!(a.fault, b.fault, "point {}: sampling depends on recovery", a.index);
        assert_eq!(b.attempts, 0, "point {}: attempts without a policy", b.index);
        assert!(!b.recovered, "point {}: recovery claimed while disabled", b.index);
    }
}
