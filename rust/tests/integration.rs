//! Integration + property tests over the public API: cross-module
//! invariants the unit tests can't see (simulator determinism, config
//! independence of numerics, metric consistency, arbitration fairness).

use transpfp::cluster::Cluster;
use transpfp::config::{ClusterConfig, Corner};
use transpfp::coordinator::{pareto_table_from, points, run_one, table45, QueryEngine};
use transpfp::isa::{regs, ProgramBuilder};
use transpfp::kernels::{Benchmark, Variant};
use transpfp::model;
use transpfp::testutil::{check_cases, Rng};
use transpfp::transfp::FpMode;

/// Simulation is deterministic: identical runs produce identical counters.
#[test]
fn determinism() {
    let cfg = ClusterConfig::new(8, 4, 1);
    let w = Benchmark::Fft.build(Variant::VEC, &cfg);
    let (s1, o1) = w.run(&cfg).unwrap();
    let (s2, o2) = w.run(&cfg).unwrap();
    assert_eq!(o1, o2);
    assert_eq!(s1.total_cycles, s2.total_cycles);
    for (a, b) in s1.per_core.iter().zip(&s2.per_core) {
        assert_eq!(a, b);
    }
}

/// Numeric results are identical across ALL cluster configurations — timing
/// parameters (sharing, pipelining) must never change values.
#[test]
fn numerics_independent_of_configuration() {
    for b in [Benchmark::Matmul, Benchmark::Dwt, Benchmark::Kmeans] {
        for v in [Variant::Scalar, Variant::VEC] {
            let reference: Option<Vec<f64>> = None;
            let mut reference = reference;
            for cfg in ClusterConfig::design_space() {
                let w = b.build(v, &cfg);
                let (_, out) = w.run(&cfg).unwrap();
                w.verify(&out).unwrap();
                match &reference {
                    None => reference = Some(out),
                    Some(r) => assert_eq!(r, &out, "{b:?} {v:?} differs on {cfg}"),
                }
            }
        }
    }
}

/// More FPUs can never make a workload slower (same cores/pipe).
#[test]
fn monotone_in_fpu_count() {
    for b in [Benchmark::Matmul, Benchmark::Fir] {
        for pipe in 0..=2 {
            let mut last = u64::MAX;
            for fpus in [2usize, 4, 8] {
                let cfg = ClusterConfig::new(8, fpus, pipe);
                let w = b.build(Variant::Scalar, &cfg);
                let (s, _) = w.run(&cfg).unwrap();
                assert!(
                    s.total_cycles <= last.saturating_add(last / 50),
                    "{b:?} pipe={pipe}: {fpus} FPUs slower ({} vs {last})",
                    s.total_cycles
                );
                last = s.total_cycles;
            }
        }
    }
}

/// More workers can never increase total cycles (parallel scaling sanity).
#[test]
fn monotone_in_workers() {
    let cfg = ClusterConfig::new(16, 16, 1);
    for b in [Benchmark::Conv, Benchmark::Fft] {
        let w = b.build(Variant::Scalar, &cfg);
        let mut last = u64::MAX;
        for workers in [1usize, 2, 4, 8, 16] {
            let (s, out) = w.run_on(&cfg, workers).unwrap();
            w.verify(&out).unwrap_or_else(|e| panic!("{workers} workers: {e}"));
            assert!(
                s.total_cycles <= last,
                "{b:?}: {workers} workers slower ({} vs {last})",
                s.total_cycles
            );
            last = s.total_cycles;
        }
    }
}

/// Property: random SPMD integer programs terminate identically on every
/// configuration (the timing model never alters architectural state).
#[test]
fn property_random_programs_config_invariant() {
    check_cases(20, |rng: &mut Rng| {
        let prog = random_int_program(rng);
        let mut reference: Option<Vec<u32>> = None;
        for cfg in [
            ClusterConfig::new(8, 2, 0),
            ClusterConfig::new(8, 8, 2),
            ClusterConfig::new(16, 4, 1),
        ] {
            let mut cl = Cluster::new(cfg, prog.clone());
            let stats = cl.run().unwrap();
            assert!(stats.total_cycles > 0);
            let out: Vec<u32> = (0..8)
                .map(|i| {
                    cl.mem.load(
                        transpfp::cluster::mem::TCDM_BASE + 4 * i,
                        transpfp::isa::MemSize::Word,
                    )
                })
                .collect();
            match &reference {
                None => reference = Some(out),
                Some(r) => assert_eq!(r, &out),
            }
        }
    });
}

/// Generate a small random (but always-terminating) SPMD program: each core
/// computes a pseudo-random function of its id and stores to its own slot.
fn random_int_program(rng: &mut Rng) -> transpfp::isa::Program {
    let mut b = ProgramBuilder::new("random");
    let iters = 4 + rng.below(12) as u32;
    b.li(1, iters);
    b.li(2, rng.next_u32() & 0xFFFF);
    b.li(3, 0);
    b.hwloop(1);
    match rng.below(4) {
        0 => {
            b.add(3, 3, 2);
            b.xor(2, 2, 3);
        }
        1 => {
            b.mul(3, 3, 2);
            b.addi(3, 3, rng.below(100) as i32);
        }
        2 => {
            b.imax(3, 3, 2);
            b.srli(2, 2, 1);
        }
        _ => {
            b.sub(3, 2, 3);
            b.slli(2, 2, 1);
        }
    }
    b.hwloop_end();
    // Mix in FP to exercise arbitration.
    b.fcvt_from_int(FpMode::F32, 4, 3);
    b.fmul(FpMode::F32, 4, 4, 4);
    b.fcvt_to_int(FpMode::F32, 5, 4);
    // Store result to the core's slot (cores 8+ reuse slots benignly —
    // identical programs on identical ids produce identical values).
    b.andi(6, regs::CORE_ID, 7);
    b.slli(6, 6, 2);
    b.li(7, transpfp::cluster::mem::TCDM_BASE);
    b.add(7, 7, 6);
    b.sw(5, 7, 0);
    b.barrier();
    b.end();
    b.build()
}

/// Golden parity of the runtime-scheduled kernels: every benchmark ×
/// ladder rung still reproduces its host-mirror golden (`expected` was
/// computed before the kernels moved onto `runtime::parallel_for` and has
/// not changed — the scalar rungs verify at rtol 0 / atol 1e-12, i.e.
/// bit-parity in f64). The scalar rungs are additionally asserted
/// *exactly* equal: the runtime only re-partitions indices, never touches
/// per-index arithmetic.
#[test]
fn runtime_scheduled_kernels_match_hand_chunked_goldens() {
    let cfg = ClusterConfig::new(8, 4, 1);
    for b in Benchmark::all() {
        for v in Variant::all() {
            let w = b.build(v, &cfg);
            let (_, out) = w.run(&cfg).unwrap();
            w.verify(&out).unwrap_or_else(|e| panic!("{b:?} {}: {e}", v.label()));
            if matches!(v, Variant::Scalar) {
                assert_eq!(out, w.expected, "{b:?} scalar must be bit-identical to the golden");
            }
        }
    }
}

/// Metric consistency: area efficiency == perf / area for every measurement.
#[test]
fn metric_identities() {
    for cfg in [ClusterConfig::new(8, 2, 2), ClusterConfig::new(16, 16, 0)] {
        let m = run_one(&cfg, Benchmark::Svm, Variant::VEC).unwrap();
        let area = model::area_mm2(&cfg);
        assert!((m.metrics.area_eff - m.metrics.perf_gflops / area).abs() < 1e-9);
        let f = model::fmax_mhz(&cfg, Corner::St);
        assert!(
            (m.metrics.perf_gflops - m.metrics.flops_per_cycle * f * 1e-3).abs() < 1e-9,
            "perf must equal flops/cycle × fmax"
        );
    }
}

/// Failure injection: a program that deadlocks (barrier never completed
/// because one core exits early) comes back as a structured error on the
/// hang path — a `RunError`, never a panic and never a stuck process.
#[test]
fn deadlock_guard_fires() {
    use transpfp::cluster::RunError;
    let mut b = ProgramBuilder::new("deadlock");
    // Core 0 exits; everyone else waits forever at the barrier.
    b.beq(regs::CORE_ID, regs::ZERO, "out");
    b.barrier();
    b.label("out");
    b.end();
    let mut cl = Cluster::new(ClusterConfig::new(8, 8, 0), b.build());
    cl.max_cycles = 10_000;
    let err = cl.run().expect_err("an incompletable barrier must not run to completion");
    assert!(
        matches!(err, RunError::Deadlock { .. } | RunError::Timeout { .. }),
        "expected a hang-class error, got {err:?}"
    );
    assert!(
        err.class() == "deadlock" || err.class() == "timeout",
        "hang-class label, got {}",
        err.class()
    );
}

/// The full paper pipeline smoke test: one measurement per benchmark on the
/// three headline configurations, everything verified.
#[test]
fn headline_configs_full_suite() {
    for mnemonic in ["16c16f1p", "16c16f0p", "8c4f1p"] {
        let cfg = ClusterConfig::parse(mnemonic).unwrap();
        for b in Benchmark::all() {
            for v in [Variant::Scalar, Variant::VEC] {
                let m = run_one(&cfg, b, v).unwrap();
                assert!(m.verified, "{mnemonic} {b:?} {v:?}");
                assert!(m.metrics.perf_gflops > 0.05);
                assert!(m.metrics.energy_eff > 5.0);
            }
        }
    }
}

/// §3.2: interleaved FPU allocation avoids contention when parallel
/// sections use fewer workers than cores; the blocked mapping doesn't.
#[test]
fn interleaved_mapping_beats_blocked_at_half_occupancy() {
    let interleaved = ClusterConfig::new(8, 4, 1);
    let blocked = ClusterConfig::new(8, 4, 1).with_blocked_fpu_map();
    let w = Benchmark::Matmul.build(Variant::Scalar, &interleaved);
    let (si, _) = w.run_on(&interleaved, 4).unwrap();
    let (sb, _) = w.run_on(&blocked, 4).unwrap();
    let cont = |s: &transpfp::cluster::counters::RunStats| -> u64 {
        s.per_core.iter().map(|c| c.fpu_cont).sum()
    };
    assert_eq!(cont(&si), 0, "interleaved: 4 workers → 4 distinct FPUs");
    assert!(cont(&sb) > 0, "blocked: neighbours share units");
    assert!(si.total_cycles <= sb.total_cycles);
}

/// §5.2: float16 and bfloat16 vectors have identical timing (the tables
/// report a single value for both) — and both verify numerically.
#[test]
fn f16_and_bf16_timing_equivalent() {
    let cfg = ClusterConfig::new(8, 8, 1);
    for b in [Benchmark::Fir, Benchmark::Matmul, Benchmark::Fft] {
        let wf = b.build(Variant::Vector(FpMode::VecF16), &cfg);
        let wb = b.build(Variant::Vector(FpMode::VecBf16), &cfg);
        let (sf, of) = wf.run(&cfg).unwrap();
        let (sb, ob) = wb.run(&cfg).unwrap();
        wf.verify(&of).unwrap();
        wb.verify(&ob).unwrap();
        let ratio = sf.total_cycles as f64 / sb.total_cycles as f64;
        assert!((ratio - 1.0).abs() < 0.01, "{b:?}: {ratio}");
    }
}

/// Acceptance gate of the memoizing query engine: regenerating Table 4 on a
/// warm cache issues **zero** simulator runs and reproduces the cold table
/// byte-for-byte.
#[test]
fn warm_cache_table4_issues_zero_simulator_runs() {
    let engine = QueryEngine::new();
    let cold = table45(&engine, 8).unwrap();
    let after_cold = engine.stats();
    // 9 eight-core configs × 8 benchmarks × 2 variants, all cold.
    assert_eq!(after_cold.misses, 144);
    assert_eq!(after_cold.hits, 0);
    assert_eq!(after_cold.entries, 144);

    let warm = table45(&engine, 8).unwrap();
    let after_warm = engine.stats();
    assert_eq!(after_warm.misses, after_cold.misses, "warm table4 must not simulate");
    assert_eq!(after_warm.hits, 144);
    assert_eq!(cold.to_csv(), warm.to_csv(), "warm table must be byte-identical");
}

/// The Pareto report is deterministic: rebuilt from the same measurements,
/// and re-resolved through the cache, it renders identically.
#[test]
fn pareto_report_is_deterministic() {
    let engine = QueryEngine::new();
    let cfgs = [ClusterConfig::new(8, 4, 1), ClusterConfig::new(8, 8, 0)];
    let pts = points(&cfgs, &[Benchmark::Fir, Benchmark::Matmul], &[Variant::Scalar, Variant::VEC]);
    let ms = engine.query(&pts).unwrap();
    let first = pareto_table_from(&ms).to_csv();
    assert_eq!(first, pareto_table_from(&ms).to_csv());
    // Warm re-query: measurements come back bit-identical from the cache,
    // so the report does too.
    let warm = engine.query(&pts).unwrap();
    assert_eq!(first, pareto_table_from(&warm).to_csv());
    assert!(first.lines().count() > 1, "frontier is non-empty");
}

/// Fingerprint collision smoke: every kernel × every rung of the precision
/// ladder decodes to a distinct program fingerprint (40 programs), and the
/// fingerprints are stable across an independent rebuild + predecode.
#[test]
fn program_fingerprints_distinct_across_kernel_suite() {
    use transpfp::isa::DecodedProgram;

    let cfg = ClusterConfig::new(8, 8, 1);
    let mut seen: Vec<(String, u64)> = Vec::new();
    for b in Benchmark::all() {
        for v in transpfp::kernels::Variant::all() {
            let w = b.build(v, &cfg);
            let fp = DecodedProgram::decode(&w.program).fingerprint();
            let name = format!("{} {}", b.name(), v.label());
            for (other, ofp) in &seen {
                assert_ne!(fp, *ofp, "fingerprint collision: {name} vs {other}");
            }
            // Rebuild + re-decode reproduces the fingerprint exactly.
            let again = b.build(v, &cfg);
            assert_eq!(DecodedProgram::decode(&again.program).fingerprint(), fp, "{name}");
            seen.push((name, fp));
        }
    }
    assert_eq!(seen.len(), 40);
}
