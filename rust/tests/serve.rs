//! Integration wall for `transpfp serve`: codec robustness under fuzzed
//! input, CLI ↔ wire request equivalence, and end-to-end single-flight
//! over real TCP connections.
//!
//! Every test leaks its own [`QueryEngine`] so the global engine (and its
//! persisted cache) is never touched and tests stay independent.

use std::io::{BufReader, Cursor, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Barrier};
use std::thread;

use transpfp::prelude::{parse_cli, Benchmark, ClusterConfig, QueryEngine, Request, Variant};
use transpfp::server::{read_reply, serve_tcp, Endpoint, QueryTier, Selector, Server, WireReply};
use transpfp::testutil::Rng;
use transpfp::tuner::{Probe, DEFAULT_BUDGET};

fn leaked_server() -> Server {
    Server::new(Box::leak(Box::new(QueryEngine::new())))
}

/// Feed a byte stream through the pipe server and decode every reply.
fn pipe(server: &Server, input: Vec<u8>) -> (transpfp::server::PipeSummary, Vec<WireReply>) {
    let mut out = Vec::new();
    let summary = server.serve_pipe(Cursor::new(input), &mut out).expect("pipe serves to EOF");
    let mut reader = Cursor::new(out);
    let mut replies = Vec::new();
    while let Some(r) = read_reply(&mut reader).expect("well-formed reply frame") {
        replies.push(r);
    }
    (summary, replies)
}

/// Fuzzed garbage never panics the codec or the router, and every input
/// line gets exactly one well-framed reply.
#[test]
fn fuzzed_lines_always_get_structured_replies() {
    let server = leaked_server();
    // Mostly printable noise, sprinkled with flag-ish tokens, separators
    // and invalid UTF-8 — none of it may panic or desync the framing.
    let pool: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789 \
        --,.<>{}\"'\\\t=:/%\xff\xfe\x00";
    let mut rng = Rng::new(0x5e12_5e12);
    let mut input = Vec::new();
    let mut expected = 0u64;
    for _ in 0..300 {
        let len = rng.below(48) as usize;
        let mut line = Vec::with_capacity(len);
        for _ in 0..len {
            line.push(pool[rng.below(pool.len() as u64) as usize]);
        }
        // Lines that trim to nothing are skipped by the server; count the
        // rest (any non-whitespace byte, valid UTF-8 or not, gets a reply).
        if !line.iter().all(|b| b.is_ascii_whitespace()) {
            expected += 1;
        }
        input.extend_from_slice(&line);
        input.push(b'\n');
    }
    let (summary, replies) = pipe(&server, input);
    assert_eq!(summary.requests, expected, "one reply per non-blank line");
    assert_eq!(summary.requests, summary.replies_ok + summary.replies_err);
    assert_eq!(replies.len() as u64, summary.requests, "every reply is decodable");
}

/// The table of known-malformed requests: always `err`, never a panic,
/// and the connection keeps serving afterwards.
#[test]
fn malformed_requests_are_structured_errors() {
    let server = leaked_server();
    let cases = [
        "query",
        "query 8c8f1p",
        "query 8c8f1p FIR",
        "query bad FIR scalar",
        "query 8c8f1p NOPE scalar",
        "query 8c8f1p FIR warp",
        "tune --budget",
        "tune --budget nan",
        "tune --budget -1",
        "tune 8c8f1p extra words",
        "pareto now",
        "run 8c2f0p FIR scalar",
        "sweep",
        "--csv query all FIR scalar",
        "query 8c2f0p FIR scalar --csv",
        "tune --jobs 4",
        "ping --port 4517",
    ];
    let input: Vec<u8> = cases.iter().map(|c| format!("{c}\n")).collect::<String>().into_bytes();
    let (summary, replies) = pipe(&server, input);
    assert_eq!(summary.requests, cases.len() as u64);
    assert_eq!(summary.replies_err, cases.len() as u64, "every malformed line is an error");
    for (case, reply) in cases.iter().zip(&replies) {
        assert!(!reply.ok, "`{case}` must fail");
        assert!(reply.head.starts_with("err bad-request "), "`{case}` → {}", reply.head);
    }
    // The stream recovers: a valid request after the garbage still works.
    let (_, replies) = pipe(&server, b"ping\n".to_vec());
    assert_eq!(replies[0].rows, vec!["pong"]);
}

/// Oversized and truncated lines: consumed, reported, recovered from.
#[test]
fn oversized_and_truncated_lines_never_desync() {
    let server = leaked_server().with_max_line(64);
    let mut input = vec![b'q'; 500];
    input.push(b'\n');
    input.extend_from_slice(b"ping\n");
    input.extend_from_slice(&[0xff, 0xfe, b'\n']);
    // Final line truncated at EOF (no newline) — still served.
    input.extend_from_slice(b"ping");
    let (summary, replies) = pipe(&server, input);
    assert_eq!(summary.requests, 4);
    assert_eq!(summary.replies_ok, 2);
    assert!(replies[0].head.starts_with("err oversized "), "{}", replies[0].head);
    assert!(replies[0].head.contains("64 bytes"), "bound named in the error");
    assert!(replies[1].ok);
    assert!(replies[2].head.starts_with("err bad-utf8 "), "{}", replies[2].head);
    assert!(replies[3].ok, "truncated final line still answered");

    // An oversized line with no newline before EOF is also structured.
    let (summary, replies) = pipe(&server, vec![b'x'; 500]);
    assert_eq!(summary.requests, 1);
    assert!(replies[0].head.starts_with("err oversized "));
}

/// The CLI and the wire build identical `Request` values, and the
/// canonical line round-trips exactly.
#[test]
fn cli_and_wire_requests_are_identical() {
    let cases: &[&[&str]] = &[
        &["query", "8c4f1p", "FIR", "scalar"],
        &["query", "all", "all", "all"],
        &["query", "16c16f2p", "MATMUL", "vector-bf16"],
        &["query", "8c4f1p", "FIR", "scalar", "--tier", "functional"],
        &["query", "8c4f1p", "FIR", "scalar", "--tier", "interpreter"],
        &["tune"],
        &["tune", "8c4f1p"],
        &["tune", "all", "--budget", "1e-3", "--probe", "cycle"],
        &["pareto"],
        &["pareto", "--acc"],
        &["inject-status"],
        &["stats"],
        &["trace"],
        &["ping"],
    ];
    for argv in cases {
        let from_cli = parse_cli(argv.iter().map(|s| s.to_string()))
            .expect("cli parse")
            .to_request()
            .expect("cli lowers to a request");
        let line = argv.join(" ");
        let from_wire = Request::parse_line(&line).expect("wire parses the same line");
        assert_eq!(from_cli, from_wire, "front ends diverged on `{line}`");
        // Canonical form round-trips exactly (floats via Display).
        let canon = from_cli.to_line();
        assert_eq!(Request::parse_line(&canon), Ok(from_cli), "round-trip of `{canon}`");
    }

    // Defaults are materialized in the typed value, not re-derived later.
    let tune = Request::parse_line("tune").unwrap();
    assert_eq!(
        tune,
        Request::Tune {
            cfg: Selector::One(ClusterConfig::new(8, 8, 1)),
            budget: DEFAULT_BUDGET,
            probe: Probe::Compiled,
        }
    );
    let q = Request::parse_line("query 8c2f0p fir scalar").unwrap();
    assert_eq!(
        q,
        Request::Query {
            cfg: Selector::One(ClusterConfig::new(8, 2, 0)),
            bench: Selector::One(Benchmark::Fir),
            variant: Selector::One(Variant::Scalar),
            tier: QueryTier::Cycle,
        }
    );
}

/// End-to-end over TCP: concurrent identical cold queries coalesce onto
/// exactly one simulator run and all clients see the same row; a warm
/// re-query is a metrics-visible cache hit.
#[test]
fn tcp_concurrent_identical_queries_simulate_once() {
    let server = Arc::new(leaked_server());
    let engine = server.engine();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let server = Arc::clone(&server);
        thread::spawn(move || serve_tcp(server, listener));
    }

    const CLIENTS: usize = 8;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let replies: Vec<WireReply> = thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| {
                let barrier = Arc::clone(&barrier);
                scope.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    barrier.wait();
                    stream.write_all(b"query 8c2f0p FIR scalar\n").unwrap();
                    let mut reader = BufReader::new(stream);
                    read_reply(&mut reader).unwrap().expect("one reply")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let first = &replies[0];
    assert!(first.ok, "cold query must succeed: {}", first.head);
    assert_eq!(first.rows.len(), 2, "header + one measurement");
    for r in &replies {
        assert_eq!(r.rows, first.rows, "all clients see the identical measurement");
    }
    assert_eq!(engine.sim_runs(), 1, "identical cold burst runs the simulator once");
    assert_eq!(engine.duplicate_runs(), 0);
    assert_eq!(engine.stats().entries, 1);

    // Warm re-query on a fresh connection: pure cache hit.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"query 8c2f0p FIR scalar\nstats\n").unwrap();
    let mut reader = BufReader::new(stream);
    let warm = read_reply(&mut reader).unwrap().unwrap();
    assert_eq!(warm.rows, first.rows, "warm reply matches the cold one");
    let stats = read_reply(&mut reader).unwrap().unwrap();
    assert!(stats.ok);
    assert!(stats.rows.iter().any(|r| r == "sim_runs,1"), "stats rows: {:?}", stats.rows);
    assert!(stats.rows.iter().any(|r| r == "duplicate_runs,0"));

    assert_eq!(engine.sim_runs(), 1, "warm re-query must not re-simulate");
    let (req, err, hits, _, _, _) = server.metrics().endpoint_snapshot(Endpoint::Query);
    assert_eq!(req, CLIENTS as u64 + 1);
    assert_eq!(err, 0);
    assert!(hits >= 1, "the warm re-query is a plan-time cache hit");
}

/// The `trace` endpoint lists one span per handled request — including
/// invalid lines — with phase timings, cache outcome, and (for queries) a
/// sim-run attribution summary derived from the resolved measurements.
#[test]
fn trace_endpoint_reports_request_spans_over_the_wire() {
    let server = leaked_server();
    let input = b"ping\nquery 8c2f0p FIR scalar\ndefinitely-not-a-request\ntrace\n".to_vec();
    let (summary, replies) = pipe(&server, input);
    assert_eq!(summary.requests, 4);
    let trace = &replies[3];
    assert!(trace.ok, "trace endpoint must succeed: {}", trace.head);
    assert_eq!(
        trace.rows[0],
        "endpoint,ok,queued_us,planned_us,simulated_us,serialized_us,hits,misses,batched,\
         attribution,request"
    );
    // ping, query, invalid — oldest first; the trace request itself is
    // recorded only after its reply is built.
    assert_eq!(trace.rows.len(), 1 + 3, "rows: {:?}", trace.rows);
    assert!(trace.rows[1].starts_with("ping,true,"), "{}", trace.rows[1]);
    assert!(trace.rows[2].starts_with("query,true,"), "{}", trace.rows[2]);
    assert!(
        trace.rows[2].contains("active") && trace.rows[2].contains("top stall"),
        "query span must carry an attribution summary: {}",
        trace.rows[2]
    );
    assert!(trace.rows[3].starts_with("invalid,false,"), "{}", trace.rows[3]);

    // A second `trace` now sees the first one as a span, and a warm
    // re-query records a hit where the cold one recorded a miss.
    let (_, replies) = pipe(&server, b"query 8c2f0p FIR scalar\ntrace\n".to_vec());
    let trace = &replies[1];
    assert_eq!(trace.rows.len(), 1 + 5, "rows: {:?}", trace.rows);
    assert!(trace.rows[4].starts_with("trace,true,"), "{}", trace.rows[4]);
    let cold: Vec<&str> = trace.rows[2].split(',').collect();
    let warm: Vec<&str> = trace.rows[5].split(',').collect();
    assert_eq!((cold[6], cold[7]), ("0", "1"), "cold query is a miss: {}", trace.rows[2]);
    assert_eq!((warm[6], warm[7]), ("1", "0"), "warm query is a hit: {}", trace.rows[5]);

    // The span count is surfaced through `stats`.
    let (_, replies) = pipe(&server, b"stats\n".to_vec());
    assert!(
        replies[0].rows.iter().any(|r| r.starts_with("trace_spans,")),
        "stats rows: {:?}",
        replies[0].rows
    );
}

/// `stats` and `inject-status` reply schema-stable structured rows.
#[test]
fn status_endpoints_reply_structured_tables() {
    let server = leaked_server();
    let (_, replies) = pipe(&server, b"inject-status\nstats\n".to_vec());

    let inject = &replies[0];
    assert!(inject.ok);
    assert_eq!(inject.rows[0], "class,count");
    assert_eq!(
        inject.rows[1..],
        ["deadlock,0".to_string(), "timeout,0".to_string(), "fault,0".to_string()]
    );

    let stats = &replies[1];
    assert!(stats.ok);
    assert_eq!(stats.rows[0], "counter,value");
    for key in [
        "cache_entries",
        "sim_runs",
        "coalesced_runs",
        "duplicate_runs",
        "requests",
        "batched_requests",
        "batched_points",
        "planner_passes",
        "codecache_evictions",
    ] {
        assert!(
            stats.rows.iter().any(|r| r.starts_with(&format!("{key},"))),
            "stats must report {key}: {:?}",
            stats.rows
        );
    }
}
