//! Trace wall: the counter taxonomy is complete (`active + stalls ==
//! cycles` per core, suite-wide, both timed engines), traced attribution
//! reconciles **exactly** with `RunStats` (independent of ring capacity),
//! region markers behave across the runtime and the tiled kernels, and the
//! DMA-overlap accounting is sane.

use transpfp::cluster::{Cluster, Engine};
use transpfp::config::ClusterConfig;
use transpfp::kernels::{Benchmark, Variant};
use transpfp::trace::{TraceConfig, TraceKind};

/// The taxonomy-completeness wall: on every kernel, every rung of the
/// 5-variant precision ladder, and both timed engines, each core's cycles
/// decompose exactly into active + categorized stalls — no uncounted
/// cycle, no "other" bucket.
#[test]
fn counters_reconcile_suite_wide() {
    let cfg = ClusterConfig::new(8, 8, 1);
    for b in Benchmark::all() {
        for v in Variant::all() {
            let w = b.build(v, &cfg);
            for engine in [Engine::Event, Engine::Reference] {
                let (stats, _) = w.run_with(&cfg, cfg.cores, engine).unwrap();
                for (ci, c) in stats.per_core.iter().enumerate() {
                    assert_eq!(
                        c.active + c.stalls(),
                        c.cycles,
                        "{} {} [{engine:?}] core {ci}: active {} + stalls {} != cycles {}",
                        b.name(),
                        v.label(),
                        c.active,
                        c.stalls(),
                        c.cycles
                    );
                }
            }
        }
    }
}

/// Traced runs produce attribution reports that reconcile exactly with
/// the run's own counters — every field of every core — on both engines,
/// and attaching the tracer does not perturb the simulation itself.
#[test]
fn traced_attribution_reconciles_exactly() {
    let cfg = ClusterConfig::new(8, 4, 1);
    for b in Benchmark::all() {
        for engine in [Engine::Event, Engine::Reference] {
            let w = b.build(Variant::Scalar, &cfg);
            let (plain, plain_out) = w.run_with(&cfg, cfg.cores, engine).unwrap();
            let (stats, out, tracer) =
                w.run_traced(&cfg, cfg.cores, engine, TraceConfig::default()).unwrap();
            let ctx = format!("{} [{engine:?}]", b.name());
            assert_eq!(out, plain_out, "{ctx}: tracing changed the outputs");
            assert_eq!(
                stats.total_cycles, plain.total_cycles,
                "{ctx}: tracing changed the cycle count"
            );
            assert_eq!(stats.per_core, plain.per_core, "{ctx}: tracing changed the counters");
            w.verify(&out).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            tracer
                .report()
                .reconcile(&stats)
                .unwrap_or_else(|e| panic!("{ctx}: attribution drift: {e}"));
        }
    }
}

/// Attribution is built from counter snapshot diffs, not ring replay, so
/// it stays exact even when a tiny ring drops almost every record.
#[test]
fn attribution_is_exact_even_when_rings_drop() {
    let cfg = ClusterConfig::new(8, 4, 1);
    let w = Benchmark::Matmul.build(Variant::Scalar, &cfg);
    let tcfg = TraceConfig { ring_capacity: 32 };
    let (stats, _, tracer) = w.run_traced(&cfg, cfg.cores, Engine::Event, tcfg).unwrap();
    let db = tracer.db();
    assert!(db.total_dropped() > 0, "fixture must overflow the 32-record rings");
    for ci in 0..db.cores() {
        assert!(db.len(ci) <= tcfg.ring_capacity, "core {ci} ring over capacity");
    }
    tracer.report().reconcile(&stats).expect("exact despite drops");
}

/// The DMA double-buffered MATMUL: per-tile regions and the runtime's
/// `dma-wait` spin region show up in the report, the DMA engine is
/// actually exercised, and the overlap efficiency is a sane fraction.
#[test]
fn tiled_matmul_reports_dma_overlap() {
    let cfg = ClusterConfig::new(8, 4, 1);
    let w = Benchmark::Matmul.build_tiled(&cfg, 4).expect("tiled MATMUL");
    let (stats, out, tracer) =
        w.run_traced(&cfg, cfg.cores, Engine::Event, TraceConfig::default()).unwrap();
    w.verify(&out).unwrap();
    let rep = tracer.report();
    rep.reconcile(&stats).expect("tiled attribution drift");
    assert!(rep.dma_busy > 0, "tiled pipeline must exercise the DMA");
    let eff = rep.dma_overlap_efficiency().expect("DMA ran, efficiency defined");
    assert!((0.0..=1.0).contains(&eff), "overlap efficiency {eff} out of [0,1]");
    let regions = rep.regions();
    assert!(regions.contains(&"dma-wait"), "missing dma-wait region: {regions:?}");
    for t in 0..4 {
        let name = format!("tile{t}");
        assert!(regions.contains(&name.as_str()), "missing {name} region: {regions:?}");
        assert!(rep.region_total(&name).cycles > 0, "{name} credited no cycles");
    }
    let db = tracer.db();
    let dma_starts: usize = (0..db.cores())
        .map(|ci| db.records(ci).filter(|r| r.kind == TraceKind::DmaStart).count())
        .sum();
    let dma_lands: usize = (0..db.cores())
        .map(|ci| db.records(ci).filter(|r| r.kind == TraceKind::DmaLand).count())
        .sum();
    assert!(dma_starts > 0, "no DMA trigger records");
    assert_eq!(dma_starts, dma_lands, "every trigger must land");
}

/// The runtime's `parallel_for` brackets the work-shared loop in a trace
/// region on every core, under every scheduling policy, and the region's
/// attribution reconciles with the run.
#[test]
fn parallel_for_emits_a_region_on_every_core() {
    use transpfp::kernels::Alloc;
    use transpfp::runtime::{parallel_for, LoopRegs, Schedule, WorkQueue};

    let cfg = ClusterConfig::new(8, 4, 1);
    let mut al = Alloc::new(&cfg);
    let queue = WorkQueue::alloc(&mut al);
    let scheds = [
        Schedule::Static,
        Schedule::Dynamic { chunk: 2, queue },
        Schedule::Guided { min_chunk: 1, queue },
    ];
    for sched in scheds {
        let mut b = transpfp::isa::ProgramBuilder::new("pf-trace");
        b.li(LoopRegs::KERNEL.n, 64);
        parallel_for(&mut b, sched, LoopRegs::KERNEL, |_| {}, |p| {
            p.addi(3, 3, 1);
        });
        b.barrier();
        b.end();
        let mut cl = Cluster::new(cfg, b.build());
        cl.attach_tracer(TraceConfig::default());
        let stats = cl.run_with(Engine::Event).unwrap();
        let tracer = cl.take_tracer().expect("tracer stays attached through the run");
        let rep = tracer.report();
        rep.reconcile(&stats).expect("parallel_for attribution drift");
        let regions = rep.regions();
        let pf = regions
            .iter()
            .find(|r| r.starts_with("pf"))
            .unwrap_or_else(|| panic!("no pf region in {regions:?}"))
            .to_string();
        let cores_in: Vec<usize> =
            rep.rows.iter().filter(|r| r.region == pf).map(|r| r.core).collect();
        assert_eq!(cores_in.len(), cfg.cores, "every core must enter {pf}");
        assert!(rep.region_total(&pf).cycles > 0);
        // Enter/exit records balance per core (the exit pc is shared with
        // the code past the loop, but every core does run the loop here).
        let db = tracer.db();
        for ci in 0..db.cores() {
            let enters = db.records(ci).filter(|r| r.kind == TraceKind::RegionEnter).count();
            let exits = db.records(ci).filter(|r| r.kind == TraceKind::RegionExit).count();
            assert_eq!(enters, exits, "core {ci}: unbalanced region markers");
        }
    }
}

/// Partial-occupancy traced runs reconcile too — parked cores contribute
/// all-zero rows, active cores their exact counters.
#[test]
fn partial_occupancy_traced_runs_reconcile() {
    let cfg = ClusterConfig::new(16, 8, 1);
    for workers in [1usize, 5, 16] {
        let w = Benchmark::Fir.build(Variant::Scalar, &cfg);
        let (stats, out, tracer) =
            w.run_traced(&cfg, workers, Engine::Event, TraceConfig::default()).unwrap();
        w.verify(&out).unwrap();
        tracer
            .report()
            .reconcile(&stats)
            .unwrap_or_else(|e| panic!("{workers} workers: {e}"));
    }
}
