//! `cargo bench --bench transfp_micro` — L1-substrate micro-benchmarks:
//! throughput of the bit-accurate softfloat ops the simulator's FP path is
//! built on. These ops dominate the simulator's per-cycle cost for
//! FP-intensive kernels, so regressions here show up directly in
//! `sim_hotpath`.

use std::time::Instant;

use transpfp::transfp::{scalar, simd, spec::F16, FpSpec};

fn bench(name: &str, iters: u64, f: impl Fn(u64) -> u32) {
    // Warm-up.
    let mut acc = 0u32;
    for i in 0..1000 {
        acc = acc.wrapping_add(f(i));
    }
    let t0 = Instant::now();
    for i in 0..iters {
        acc = acc.wrapping_add(f(i));
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("  {name:24} {:>8.1} M ops/s   (sink {acc:08x})", iters as f64 / dt / 1e6);
}

fn main() {
    const N: u64 = 2_000_000;
    let spec: &FpSpec = &F16;
    println!("transfp softfloat micro-benchmarks ({N} iterations):");

    bench("f32 fma (native)", N, |i| {
        scalar::fma32((i as u32) | 0x3f80_0000, 0x3f00_0000, 0x3e80_0000)
    });
    bench("f16 add", N, |i| scalar::add16(spec, (i as u16) & 0x7bff, 0x3c00) as u32);
    bench("f16 fma", N, |i| {
        scalar::fma16(spec, (i as u16) & 0x7bff, 0x3800, 0x3c00) as u32
    });
    bench("f16→f64 decode", N, |i| spec.to_f64((i as u16) & 0x7bff) as u32);
    bench("f64→f16 encode", N, |i| spec.from_f64(i as f64 * 0.001) as u32);
    bench("vec2 f16 vmac", N, |i| simd::vmac(spec, i as u32, 0x3c00_3c00, 0x0000_3c00));
    bench("vec2 f16 dotp widen", N, |i| simd::vdotp_widen(spec, i as u32, 0x3c00_3c00, 0));
    bench("cast-and-pack", N, |i| {
        transpfp::transfp::cast::cpka(spec, (i as u32) | 0x3f80_0000, 0x3f00_0000)
    });
}
