//! `cargo bench --bench backend` — gates the tiered execution backends.
//!
//! Three gates (process exits non-zero on violation):
//!
//! 1. **Throughput tier**: on the kernel suite (all 8 benchmarks ×
//!    {scalar, vector-f16}) over the max-sharing `8c2f2p` configuration —
//!    the event engine's slowest per-instruction regime (FPU-port and
//!    TCDM arbitration on most instructions, write-back conflicts at two
//!    pipeline stages) — the functional backend must retire instructions
//!    at ≥ 50× the event engine's rate. Both tiers are measured on fresh
//!    state per repetition over identical workloads.
//! 2. **Compiled tier**: same suite — the compiled backend (pre-resolved
//!    fused-block translation, warm code cache) must retire instructions
//!    at ≥ 5× the functional interpreter's rate, with retired counts
//!    bit-identical to the event engine's, translating each distinct
//!    program exactly once. The translation-cache hit/miss counters are
//!    printed for the CI summary.
//! 3. **Tuner probe**: `tune` with the default functional probe issues
//!    exactly one functional run per ladder rung and **zero**
//!    cycle-accurate runs for accuracy-rejected rungs (checked
//!    point-by-point against the measurement cache).
//!
//! The `backend-*` lines below are grepped into the CI step summary.

use std::process::ExitCode;
use std::time::Instant;

use transpfp::cluster::backend::BackendKind;
use transpfp::config::ClusterConfig;
use transpfp::coordinator::query::QueryPoint;
use transpfp::coordinator::QueryEngine;
use transpfp::kernels::{Benchmark, Variant, Workload};
use transpfp::tuner::{tune_with, DEFAULT_BUDGET, LADDER};

const MIN_RATIO: f64 = 50.0;
/// The compiled tier must beat the functional interpreter by at least this
/// factor on instruction throughput (same suite, bit-identical retirement).
const MIN_COMPILED_RATIO: f64 = 5.0;

/// Retired instructions and wall seconds for one pass of `workloads` on a
/// backend.
fn measure(
    cfg: &ClusterConfig,
    workloads: &[Workload],
    kind: BackendKind,
    reps: usize,
) -> (u64, f64) {
    let mut instrs = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        for w in workloads {
            let (run, out) = w.run_on_backend(cfg, cfg.cores, kind.get())
                .expect("suite workloads terminate on every tier");
            assert!(w.verify(&out).is_ok(), "{}: {:?} run failed to verify", w.name, kind);
            instrs += run.instrs;
        }
    }
    (instrs, t0.elapsed().as_secs_f64())
}

fn main() -> ExitCode {
    let mut ok = true;

    // ---- Gate 1: instruction throughput, functional vs event.
    let cfg = ClusterConfig::new(8, 2, 2);
    let workloads: Vec<Workload> = Benchmark::all()
        .into_iter()
        .flat_map(|b| [b.build(Variant::Scalar, &cfg), b.build(Variant::VEC, &cfg)])
        .collect();
    // Warm-up pass (page-faults, lazy allocations) outside the timers.
    let _ = measure(&cfg, &workloads, BackendKind::Functional, 1);
    let (ev_instrs, ev_s) = measure(&cfg, &workloads, BackendKind::Event, 1);
    let (fu_instrs, fu_s) = measure(&cfg, &workloads, BackendKind::Functional, 10);
    let ev_mips = ev_instrs as f64 / ev_s.max(1e-9) / 1e6;
    let fu_mips = fu_instrs as f64 / fu_s.max(1e-9) / 1e6;
    let ratio = fu_mips / ev_mips.max(1e-9);
    println!("backend-event-minstr-per-s: {ev_mips:.1}");
    println!("backend-functional-minstr-per-s: {fu_mips:.1}");
    println!("backend-throughput-ratio: {ratio:.0}x");
    if fu_instrs != 10 * ev_instrs {
        eprintln!(
            "FAIL: retired-instruction counts diverge across tiers \
             ({ev_instrs} event vs {fu_instrs}/10 functional)"
        );
        ok = false;
    }
    if ratio < MIN_RATIO {
        eprintln!("FAIL: functional/event throughput {ratio:.1}x below the {MIN_RATIO}x gate");
        ok = false;
    }

    // ---- Gate 2: compiled tier vs the functional interpreter.
    // Warm-up pass also populates the global translation cache, so the
    // timed passes measure execution, not translation.
    let _ = measure(&cfg, &workloads, BackendKind::Compiled, 1);
    let (co_instrs, co_s) = measure(&cfg, &workloads, BackendKind::Compiled, 10);
    let co_mips = co_instrs as f64 / co_s.max(1e-9) / 1e6;
    let co_ratio = co_mips / fu_mips.max(1e-9);
    let (cc_hits, cc_misses) = transpfp::cluster::CodeCache::global().stats();
    println!("backend-compiled-minstr-per-s: {co_mips:.1}");
    println!("backend-compiled-vs-functional-ratio: {co_ratio:.1}x");
    println!("backend-codecache-hits: {cc_hits}");
    println!("backend-codecache-misses: {cc_misses}");
    if co_instrs != 10 * ev_instrs {
        eprintln!(
            "FAIL: retired-instruction counts diverge across tiers \
             ({ev_instrs} event vs {co_instrs}/10 compiled)"
        );
        ok = false;
    }
    if co_ratio < MIN_COMPILED_RATIO {
        eprintln!(
            "FAIL: compiled/functional throughput {co_ratio:.1}x below the \
             {MIN_COMPILED_RATIO}x gate"
        );
        ok = false;
    }
    if cc_misses != workloads.len() as u64 {
        eprintln!(
            "FAIL: expected one translation per distinct program ({}), saw {cc_misses}",
            workloads.len()
        );
        ok = false;
    }

    // ---- Gate 3: the functional tune probe never pays for rejected rungs.
    let engine = QueryEngine::new();
    let tcfg = ClusterConfig::new(8, 8, 1);
    let budget = DEFAULT_BUDGET;
    let report = tune_with(&engine, &tcfg, budget).expect("tune completes on a clean engine");
    let functional_runs = engine.functional_runs();
    let sim_runs = engine.sim_runs();
    println!("backend-tune-functional-runs: {functional_runs}");
    println!("backend-tune-ca-runs: {sim_runs}");
    let ladder_points = 8 * LADDER.len() as u64;
    if functional_runs != ladder_points {
        eprintln!("FAIL: expected {ladder_points} functional probes, saw {functional_runs}");
        ok = false;
    }
    if sim_runs > ladder_points || sim_runs < 8 {
        eprintln!("FAIL: implausible cycle-accurate run count {sim_runs}");
        ok = false;
    }
    let mut rejected = 0u64;
    for c in &report.choices {
        for (ri, &v) in LADDER.iter().enumerate() {
            let probe = engine
                .query(&[QueryPoint::functional(&tcfg, c.bench, v)])
                .expect("probe is cached")
                .pop()
                .expect("cached probe");
            let adm = probe.verified && probe.err.within(budget);
            let plan = engine.plan(&[QueryPoint::new(&tcfg, c.bench, v)]);
            let cached_ca = plan.hit_count() == 1;
            if ri == 0 || adm {
                if !cached_ca {
                    eprintln!("FAIL: {} rung {ri} admissible but not simulated", c.bench.name());
                    ok = false;
                }
            } else {
                rejected += 1;
                if cached_ca {
                    eprintln!(
                        "FAIL: {} rung {ri} was accuracy-rejected yet ran cycle-accurately",
                        c.bench.name()
                    );
                    ok = false;
                }
            }
        }
    }
    println!("backend-tune-rejected-rungs: {rejected}");
    if engine.functional_runs() != functional_runs || engine.sim_runs() != sim_runs {
        eprintln!("FAIL: the audit itself issued backend runs");
        ok = false;
    }

    if !ok {
        return ExitCode::FAILURE;
    }
    println!(
        "backend: OK ({ratio:.0}x >= {MIN_RATIO}x, compiled {co_ratio:.1}x >= \
         {MIN_COMPILED_RATIO}x, no CA runs for {rejected} rejected rungs)"
    );
    ExitCode::SUCCESS
}
