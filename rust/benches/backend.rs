//! `cargo bench --bench backend` — gates the tiered execution backends.
//!
//! Three gates (process exits non-zero on violation):
//!
//! 1. **Throughput tier**: on the kernel suite (all 8 benchmarks ×
//!    {scalar, vector-f16}) over the max-sharing `8c2f2p` configuration —
//!    the event engine's slowest per-instruction regime (FPU-port and
//!    TCDM arbitration on most instructions, write-back conflicts at two
//!    pipeline stages) — the functional backend must retire instructions
//!    at ≥ 50× the event engine's rate. Both tiers are measured on fresh
//!    state per repetition over identical workloads.
//! 2. **Compiled tier**: same suite, split by shape — on the
//!    loop-dominated kernels (FIR, MATMUL, KMEANS — where the paper's
//!    cycles are, and where loop traces retire whole iterations per
//!    dispatch) the compiled backend must beat the functional interpreter
//!    by ≥ 10× on instruction throughput; on the straight-line remainder
//!    (fused blocks only) by ≥ 5×. Retired counts must stay bit-identical
//!    to the event engine's on both subsets, translating each distinct
//!    program exactly once (warm code cache). The translation-cache
//!    hit/miss counters are printed for the CI summary.
//! 3. **Tuner probe**: `tune` with the default compiled probe issues
//!    exactly one compiled run per ladder rung and **zero**
//!    cycle-accurate runs for accuracy-rejected rungs (checked
//!    point-by-point against the measurement cache).
//!
//! The `backend-*` lines below are grepped into the CI step summary.

use std::process::ExitCode;
use std::time::Instant;

use transpfp::cluster::backend::BackendKind;
use transpfp::config::ClusterConfig;
use transpfp::coordinator::query::QueryPoint;
use transpfp::coordinator::QueryEngine;
use transpfp::kernels::{Benchmark, Variant, Workload};
use transpfp::tuner::{tune_with, DEFAULT_BUDGET, LADDER};

const MIN_RATIO: f64 = 50.0;
/// Compiled vs functional instruction throughput on the loop-dominated
/// kernels, where loop traces batch whole iterations per dispatch.
const MIN_COMPILED_LOOP_RATIO: f64 = 10.0;
/// Compiled vs functional on the straight-line remainder (fused blocks).
const MIN_COMPILED_STRAIGHT_RATIO: f64 = 5.0;

/// The kernels whose inner loops dominate retirement — the subset the
/// loop-trace gate measures.
const LOOP_DOMINATED: [Benchmark; 3] = [Benchmark::Fir, Benchmark::Matmul, Benchmark::Kmeans];

/// Retired instructions and wall seconds for one pass of `workloads` on a
/// backend.
fn measure(
    cfg: &ClusterConfig,
    workloads: &[Workload],
    kind: BackendKind,
    reps: usize,
) -> (u64, f64) {
    let mut instrs = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        for w in workloads {
            let (run, out) = w.run_on_backend(cfg, cfg.cores, kind.get())
                .expect("suite workloads terminate on every tier");
            assert!(w.verify(&out).is_ok(), "{}: {:?} run failed to verify", w.name, kind);
            instrs += run.instrs;
        }
    }
    (instrs, t0.elapsed().as_secs_f64())
}

fn mips(instrs: u64, secs: f64) -> f64 {
    instrs as f64 / secs.max(1e-9) / 1e6
}

fn main() -> ExitCode {
    let mut ok = true;

    // ---- Gate 1: instruction throughput, functional vs event.
    let cfg = ClusterConfig::new(8, 2, 2);
    let build = |benches: &[Benchmark]| -> Vec<Workload> {
        benches
            .iter()
            .flat_map(|b| [b.build(Variant::Scalar, &cfg), b.build(Variant::VEC, &cfg)])
            .collect()
    };
    let loop_workloads = build(&LOOP_DOMINATED);
    let straight_benches: Vec<Benchmark> =
        Benchmark::all().into_iter().filter(|b| !LOOP_DOMINATED.contains(b)).collect();
    let straight_workloads = build(&straight_benches);
    let suite_len = loop_workloads.len() + straight_workloads.len();
    // Warm-up pass (page-faults, lazy allocations) outside the timers.
    let _ = measure(&cfg, &loop_workloads, BackendKind::Functional, 1);
    let _ = measure(&cfg, &straight_workloads, BackendKind::Functional, 1);
    let (ev_loop_instrs, ev_loop_s) = measure(&cfg, &loop_workloads, BackendKind::Event, 1);
    let (ev_str_instrs, ev_str_s) = measure(&cfg, &straight_workloads, BackendKind::Event, 1);
    let (ev_instrs, ev_s) = (ev_loop_instrs + ev_str_instrs, ev_loop_s + ev_str_s);
    let (fu_loop_instrs, fu_loop_s) = measure(&cfg, &loop_workloads, BackendKind::Functional, 10);
    let (fu_str_instrs, fu_str_s) = measure(&cfg, &straight_workloads, BackendKind::Functional, 10);
    let (fu_instrs, fu_s) = (fu_loop_instrs + fu_str_instrs, fu_loop_s + fu_str_s);
    let ev_mips = mips(ev_instrs, ev_s);
    let fu_mips = mips(fu_instrs, fu_s);
    let ratio = fu_mips / ev_mips.max(1e-9);
    println!("backend-event-minstr-per-s: {ev_mips:.1}");
    println!("backend-functional-minstr-per-s: {fu_mips:.1}");
    println!("backend-throughput-ratio: {ratio:.0}x");
    if fu_instrs != 10 * ev_instrs {
        eprintln!(
            "FAIL: retired-instruction counts diverge across tiers \
             ({ev_instrs} event vs {fu_instrs}/10 functional)"
        );
        ok = false;
    }
    if ratio < MIN_RATIO {
        eprintln!("FAIL: functional/event throughput {ratio:.1}x below the {MIN_RATIO}x gate");
        ok = false;
    }

    // ---- Gate 2: compiled tier vs the functional interpreter, split by
    // kernel shape (loop traces vs fused blocks).
    // Warm-up pass also populates the global translation cache, so the
    // timed passes measure execution, not translation.
    let _ = measure(&cfg, &loop_workloads, BackendKind::Compiled, 1);
    let _ = measure(&cfg, &straight_workloads, BackendKind::Compiled, 1);
    let (co_loop_instrs, co_loop_s) = measure(&cfg, &loop_workloads, BackendKind::Compiled, 10);
    let (co_str_instrs, co_str_s) = measure(&cfg, &straight_workloads, BackendKind::Compiled, 10);
    let co_mips = mips(co_loop_instrs + co_str_instrs, co_loop_s + co_str_s);
    let loop_ratio = mips(co_loop_instrs, co_loop_s) / mips(fu_loop_instrs, fu_loop_s).max(1e-9);
    let straight_ratio =
        mips(co_str_instrs, co_str_s) / mips(fu_str_instrs, fu_str_s).max(1e-9);
    let (cc_hits, cc_misses) = transpfp::cluster::CodeCache::global().stats();
    println!("backend-compiled-minstr-per-s: {co_mips:.1}");
    println!("backend-compiled-loop-ratio: {loop_ratio:.1}x");
    println!("backend-compiled-straight-ratio: {straight_ratio:.1}x");
    println!("backend-codecache-hits: {cc_hits}");
    println!("backend-codecache-misses: {cc_misses}");
    if co_loop_instrs != 10 * ev_loop_instrs || co_str_instrs != 10 * ev_str_instrs {
        eprintln!(
            "FAIL: retired-instruction counts diverge across tiers \
             (event {ev_loop_instrs}+{ev_str_instrs} vs compiled \
             {co_loop_instrs}/10+{co_str_instrs}/10)"
        );
        ok = false;
    }
    if loop_ratio < MIN_COMPILED_LOOP_RATIO {
        eprintln!(
            "FAIL: compiled/functional loop-kernel throughput {loop_ratio:.1}x below the \
             {MIN_COMPILED_LOOP_RATIO}x gate"
        );
        ok = false;
    }
    if straight_ratio < MIN_COMPILED_STRAIGHT_RATIO {
        eprintln!(
            "FAIL: compiled/functional straight-line throughput {straight_ratio:.1}x below \
             the {MIN_COMPILED_STRAIGHT_RATIO}x gate"
        );
        ok = false;
    }
    if cc_misses != suite_len as u64 {
        eprintln!(
            "FAIL: expected one translation per distinct program ({suite_len}), saw {cc_misses}"
        );
        ok = false;
    }

    // ---- Gate 3: the default (compiled) tune probe never pays for
    // rejected rungs and never touches the slower interpreter.
    let engine = QueryEngine::new();
    let tcfg = ClusterConfig::new(8, 8, 1);
    let budget = DEFAULT_BUDGET;
    let report = tune_with(&engine, &tcfg, budget).expect("tune completes on a clean engine");
    let compiled_runs = engine.compiled_runs();
    let sim_runs = engine.sim_runs();
    println!("backend-tune-compiled-runs: {compiled_runs}");
    println!("backend-tune-ca-runs: {sim_runs}");
    let ladder_points = 8 * LADDER.len() as u64;
    if compiled_runs != ladder_points {
        eprintln!("FAIL: expected {ladder_points} compiled probes, saw {compiled_runs}");
        ok = false;
    }
    if engine.functional_runs() != 0 {
        eprintln!(
            "FAIL: the compiled probe fell back to the interpreter \
             ({} functional runs)",
            engine.functional_runs()
        );
        ok = false;
    }
    if sim_runs > ladder_points || sim_runs < 8 {
        eprintln!("FAIL: implausible cycle-accurate run count {sim_runs}");
        ok = false;
    }
    let mut rejected = 0u64;
    for c in &report.choices {
        for (ri, &v) in LADDER.iter().enumerate() {
            let probe = engine
                .query(&[QueryPoint::functional(&tcfg, c.bench, v)])
                .expect("probe is cached")
                .pop()
                .expect("cached probe");
            let adm = probe.verified && probe.err.within(budget);
            let plan = engine.plan(&[QueryPoint::new(&tcfg, c.bench, v)]);
            let cached_ca = plan.hit_count() == 1;
            if ri == 0 || adm {
                if !cached_ca {
                    eprintln!("FAIL: {} rung {ri} admissible but not simulated", c.bench.name());
                    ok = false;
                }
            } else {
                rejected += 1;
                if cached_ca {
                    eprintln!(
                        "FAIL: {} rung {ri} was accuracy-rejected yet ran cycle-accurately",
                        c.bench.name()
                    );
                    ok = false;
                }
            }
        }
    }
    println!("backend-tune-rejected-rungs: {rejected}");
    if engine.compiled_runs() != compiled_runs || engine.sim_runs() != sim_runs {
        eprintln!("FAIL: the audit itself issued backend runs");
        ok = false;
    }

    if !ok {
        return ExitCode::FAILURE;
    }
    println!(
        "backend: OK ({ratio:.0}x >= {MIN_RATIO}x, compiled loops {loop_ratio:.1}x >= \
         {MIN_COMPILED_LOOP_RATIO}x / straight {straight_ratio:.1}x >= \
         {MIN_COMPILED_STRAIGHT_RATIO}x, no CA runs for {rejected} rejected rungs)"
    );
    ExitCode::SUCCESS
}
