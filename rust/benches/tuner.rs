//! `cargo bench --bench tuner` — gates the accuracy-aware autotuner's
//! cache behaviour (mirrors `query_cache.rs`).
//!
//! Tunes all 8 benchmarks on 8c8f1p twice on a private query engine. Since
//! the compiled tier became the default probe, the cold pass probes the
//! full 5-rung ladder (40 points) on the **compiled** backend and
//! simulates cycle-accurately only the baselines plus the
//! budget-admissible rungs; the warm pass must resolve entirely from the
//! measurement cache. Gates (process exits non-zero on violation):
//!
//! * the cold tune issues exactly 40 compiled probes, and between 8
//!   (baselines) and 40 cycle-accurate runs — one per admissible rung;
//! * the warm tune issues **zero** runs of either tier;
//! * the warm tune resolves ≥ 10× faster than cold;
//! * warm selections are identical to cold (same rung, bit-equal error);
//! * with the default 1e-2 budget, at least half of the benchmarks select
//!   a sub-binary32 variant and every selection is within budget.
//!
//! The `tune-*` lines below are grepped into the CI step summary.

use std::process::ExitCode;
use std::time::Instant;

use transpfp::config::ClusterConfig;
use transpfp::coordinator::QueryEngine;
use transpfp::tuner::{tune_with, DEFAULT_BUDGET, LADDER};

const LADDER_POINTS: u64 = 8 * LADDER.len() as u64;
const MIN_SPEEDUP: f64 = 10.0;

fn main() -> ExitCode {
    let engine = QueryEngine::new();
    let cfg = ClusterConfig::new(8, 8, 1);

    let t0 = Instant::now();
    let cold = tune_with(&engine, &cfg, DEFAULT_BUDGET).expect("cold tune completes");
    let cold_s = t0.elapsed().as_secs_f64();
    let after_cold = engine.stats();
    let cold_probe = engine.compiled_runs();
    let cold_sim = engine.sim_runs();

    let t1 = Instant::now();
    let warm = tune_with(&engine, &cfg, DEFAULT_BUDGET).expect("warm tune completes");
    let warm_s = t1.elapsed().as_secs_f64();
    let after_warm = engine.stats();

    let warm_misses = after_warm.misses - after_cold.misses;
    let warm_probe = engine.compiled_runs() - cold_probe;
    let warm_sim = engine.sim_runs() - cold_sim;
    let speedup = cold_s / warm_s.max(1e-9);

    println!("tune-cold-seconds: {cold_s:.3}");
    println!("tune-warm-seconds: {warm_s:.6}");
    println!("tune-speedup: {speedup:.0}x");
    println!("tune-cold-compiled-probes: {cold_probe}");
    println!("tune-cold-ca-runs: {cold_sim}");
    println!("tune-warm-misses: {warm_misses}");
    println!("tune-sub-f32-selections: {}/{}", cold.sub_f32_count(), cold.choices.len());
    for c in &cold.choices {
        println!(
            "tune-choice: {} -> {} (rel_err {:.3e}, eeff x{:.2})",
            c.bench.name(),
            c.chosen.variant.label(),
            c.chosen.err.rel,
            c.eeff_gain()
        );
    }

    let mut ok = true;
    if cold_probe != LADDER_POINTS {
        eprintln!(
            "FAIL: cold tune should probe {LADDER_POINTS} rungs on the compiled tier, \
             saw {cold_probe}"
        );
        ok = false;
    }
    if engine.functional_runs() != 0 {
        eprintln!(
            "FAIL: the compiled probe fell back to the interpreter ({} functional runs)",
            engine.functional_runs()
        );
        ok = false;
    }
    if cold_sim < 8 || cold_sim > LADDER_POINTS {
        eprintln!(
            "FAIL: cold tune should simulate between 8 baselines and {LADDER_POINTS} rungs, \
             saw {cold_sim}"
        );
        ok = false;
    }
    if after_cold.misses != cold_probe + cold_sim {
        eprintln!(
            "FAIL: cold misses {} should equal probes + simulations {}",
            after_cold.misses,
            cold_probe + cold_sim
        );
        ok = false;
    }
    if warm_misses != 0 || warm_probe != 0 || warm_sim != 0 {
        eprintln!(
            "FAIL: warm-cache tune issued {warm_misses} misses / {warm_probe} compiled / \
             {warm_sim} cycle-accurate runs (must all be 0)"
        );
        ok = false;
    }
    if speedup < MIN_SPEEDUP {
        eprintln!("FAIL: warm-vs-cold speedup {speedup:.1}x below the {MIN_SPEEDUP}x gate");
        ok = false;
    }
    for (a, b) in cold.choices.iter().zip(&warm.choices) {
        if a.rung != b.rung || a.chosen.err.rel.to_bits() != b.chosen.err.rel.to_bits() {
            eprintln!("FAIL: warm selection for {} diverged from cold", a.bench.name());
            ok = false;
        }
    }
    if cold.sub_f32_count() * 2 < cold.choices.len() {
        eprintln!(
            "FAIL: budget {DEFAULT_BUDGET:e} selected sub-F32 for only {}/{} benchmarks",
            cold.sub_f32_count(),
            cold.choices.len()
        );
        ok = false;
    }
    if !cold.all_within_budget() {
        eprintln!("FAIL: a selection's measured error exceeds the budget");
        ok = false;
    }
    if !ok {
        return ExitCode::FAILURE;
    }
    println!("tuner: OK (zero warm misses, {speedup:.0}x >= {MIN_SPEEDUP}x)");
    ExitCode::SUCCESS
}
