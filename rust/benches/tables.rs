//! `cargo bench --bench tables` — regenerates Tables 3–6 of the paper
//! (the full benchmark sweep on the cycle-accurate simulator) and times
//! each. This is the paper-reproduction bench: the printed tables are the
//! artifact; the timings gate the simulator's end-to-end throughput.

use std::time::Instant;

use transpfp::coordinator::QueryEngine;

fn timed<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let r = f();
    eprintln!("[bench] {name}: {:.2}s", t0.elapsed().as_secs_f64());
    r
}

fn main() {
    println!("================ Table 3 — FP/memory intensity (measured vs paper) ================");
    let t = timed("table3", || transpfp::coordinator::table3(QueryEngine::global()))
        .expect("table3 sweep completes");
    println!("{}", t.render());

    println!("================ Table 4 — 8-core configurations ================");
    let t = timed("table4", || transpfp::coordinator::table45(QueryEngine::global(), 8))
        .expect("table4 sweep completes");
    println!("{}", t.render());

    println!("================ Table 5 — 16-core configurations ================");
    let t = timed("table5", || transpfp::coordinator::table45(QueryEngine::global(), 16))
        .expect("table5 sweep completes");
    println!("{}", t.render());

    println!("================ Table 6 — state-of-the-art comparison ================");
    let t = timed("table6", || transpfp::coordinator::table6(QueryEngine::global()))
        .expect("table6 sweep completes");
    println!("{}", t.render());
}
