//! `cargo bench --bench serve` — gates for the concurrent query service.
//!
//! Three hard gates (printed as `serve-*:` lines, FAIL lines on violation):
//!
//! 1. **Zero-duplicate-runs**: 64 concurrent connections issuing the same
//!    cold query must execute the simulator exactly once (single-flight),
//!    and every client must receive the identical measurement row.
//! 2. **Batched miss planning**: 64 concurrent connections issuing 64
//!    *distinct* cold queries must land in at most two planner passes
//!    (the engine's cross-request batch queue), with zero duplicate runs.
//! 3. **Warm throughput**: with a 16-point working set resident in the
//!    cache, 8 pipelined connections must sustain >= 100k queries/s, with
//!    zero additional simulator runs during the measured phase.
//!
//! `--emit-load <n> [seed]` instead prints a seeded mixed request stream
//! (query/tune/pareto/stats/inject-status/ping) for the CI smoke step,
//! which pipes it into `transpfp serve --stdin`.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::process::ExitCode;
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Instant;

use transpfp::coordinator::QueryEngine;
use transpfp::server::{read_reply, serve_tcp, Endpoint, Server, WireReply};
use transpfp::testutil::Rng;

/// Seeded mixed request stream for the smoke test. Weighted toward warm
/// repeat queries so the daemon's hit rate is provably nonzero.
fn emit_load(n: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let cfgs = ["8c8f1p", "8c4f1p"];
    let benches = ["FIR", "MATMUL", "CONV", "DWT"];
    let variants = ["scalar", "vector-f16"];
    for _ in 0..n {
        let cfg = cfgs[rng.below(cfgs.len() as u64) as usize];
        let bench = benches[rng.below(benches.len() as u64) as usize];
        let variant = variants[rng.below(variants.len() as u64) as usize];
        let roll = rng.below(1000);
        if roll < 700 {
            println!("query {cfg} {bench} {variant}");
        } else if roll < 820 {
            println!("query {cfg} all {variant}");
        } else if roll < 900 {
            println!("query {cfg} {bench} all");
        } else if roll < 960 {
            println!("tune {cfg}");
        } else if roll < 970 {
            println!("pareto");
        } else if roll < 980 {
            println!("stats");
        } else if roll < 990 {
            println!("inject-status");
        } else {
            println!("ping");
        }
    }
}

fn send_one(addr: std::net::SocketAddr, line: &str) -> WireReply {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(line.as_bytes()).expect("send");
    stream.write_all(b"\n").expect("send newline");
    let mut reader = BufReader::new(stream);
    read_reply(&mut reader).expect("framed reply").expect("reply before EOF")
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some("--emit-load") {
        let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1000);
        let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1);
        emit_load(n, seed);
        return ExitCode::SUCCESS;
    }
    // Cargo's bench harness passes --bench; ignore it and any filters.

    let engine: &'static QueryEngine = Box::leak(Box::new(QueryEngine::new()));
    let server = Arc::new(Server::new(engine));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    {
        let server = Arc::clone(&server);
        thread::spawn(move || serve_tcp(server, listener));
    }

    let mut failed = false;

    // ---- Gate 1: 64 concurrent identical cold requests, 1 simulator run.
    const CLIENTS: usize = 64;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let t0 = Instant::now();
    let replies: Vec<WireReply> = thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| {
                let barrier = Arc::clone(&barrier);
                scope.spawn(move || {
                    barrier.wait();
                    send_one(addr, "query 8c8f1p FIR scalar")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let cold_secs = t0.elapsed().as_secs_f64();

    let first = &replies[0];
    if !first.ok || first.rows.len() != 2 {
        eprintln!("FAIL: cold query reply malformed: {:?}", first.head);
        failed = true;
    }
    if !replies.iter().all(|r| r.ok && r.rows == first.rows) {
        eprintln!("FAIL: {CLIENTS} concurrent identical queries returned divergent replies");
        failed = true;
    }
    let cold_sim_runs = engine.sim_runs();
    if cold_sim_runs != 1 {
        eprintln!(
            "FAIL: {CLIENTS} concurrent identical cold requests ran the simulator \
             {cold_sim_runs} times (must be exactly 1)"
        );
        failed = true;
    }
    if engine.duplicate_runs() != 0 {
        eprintln!("FAIL: duplicate simulator runs after the cold burst");
        failed = true;
    }
    println!("serve-cold-burst-clients: {CLIENTS}");
    println!("serve-cold-burst-secs: {cold_secs:.3}");
    println!("serve-sim-runs: {cold_sim_runs}");
    println!("serve-coalesced-runs: {}", engine.coalesced_runs());

    // ---- Gate 1b: 64 concurrent *distinct* cold requests batch their
    // misses into at most two planner passes (cross-request batching),
    // still with zero duplicate runs. `--tier functional` keeps these
    // probes on the compiled backend, so the cycle-accurate sim-run
    // accounting of the warm-up gate below is untouched.
    let distinct: Vec<String> = {
        let benches = ["FIR", "MATMUL", "CONV", "DWT", "FFT", "IIR", "KMEANS", "SVM"];
        let variants = ["scalar", "scalar-f16", "vector-f16", "vector-bf16"];
        ["8c8f1p", "8c4f1p"]
            .iter()
            .flat_map(|c| {
                benches.iter().flat_map(move |b| {
                    variants.iter().map(move |v| format!("query {c} {b} {v} --tier functional"))
                })
            })
            .collect()
    };
    assert_eq!(distinct.len(), CLIENTS, "the distinct burst must fill all {CLIENTS} clients");
    let passes_before = engine.planner_passes();
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let t0 = Instant::now();
    let distinct_ok = thread::scope(|scope| {
        let handles: Vec<_> = distinct
            .iter()
            .map(|line| {
                let barrier = Arc::clone(&barrier);
                scope.spawn(move || {
                    barrier.wait();
                    send_one(addr, line)
                })
            })
            .collect();
        handles.into_iter().all(|h| h.join().expect("client thread").ok)
    });
    let distinct_secs = t0.elapsed().as_secs_f64();
    let planner_passes = engine.planner_passes() - passes_before;
    if !distinct_ok {
        eprintln!("FAIL: a distinct cold query returned an error reply");
        failed = true;
    }
    if planner_passes > 2 {
        eprintln!(
            "FAIL: {CLIENTS} concurrent distinct cold requests took {planner_passes} \
             planner passes (must batch into <= 2)"
        );
        failed = true;
    }
    if engine.batched_points() == 0 {
        eprintln!("FAIL: no cross-request miss batching during the distinct burst");
        failed = true;
    }
    if engine.duplicate_runs() != 0 {
        eprintln!("FAIL: duplicate simulator runs after the distinct burst");
        failed = true;
    }
    println!("serve-distinct-burst-secs: {distinct_secs:.3}");
    println!("serve-batched-requests: {}", engine.batched_requests());
    println!("serve-batched-points: {}", engine.batched_points());
    println!("serve-planner-passes: {planner_passes}");

    // ---- Warm a 16-point working set (one pipelined connection).
    let warm_set: Vec<String> = {
        let benches = ["FIR", "MATMUL", "CONV", "DWT", "FFT", "IIR", "KMEANS", "SVM"];
        benches
            .iter()
            .flat_map(|b| {
                ["scalar", "vector-f16"].iter().map(move |v| format!("query 8c8f1p {b} {v}"))
            })
            .collect()
    };
    {
        let stream = TcpStream::connect(addr).expect("connect");
        let mut writer = BufWriter::new(stream.try_clone().expect("clone"));
        let mut reader = BufReader::new(stream);
        for q in &warm_set {
            writeln!(writer, "{q}").expect("send");
        }
        writer.flush().expect("flush");
        for _ in 0..warm_set.len() {
            let r = read_reply(&mut reader).expect("framed").expect("reply");
            if !r.ok {
                eprintln!("FAIL: warm-up query failed: {}", r.head);
                failed = true;
            }
        }
    }
    let warm_sim_runs = engine.sim_runs();

    // ---- Gate 2: >= 100k warm queries/s across 8 pipelined connections.
    const CONNS: usize = 8;
    const PER_CONN: usize = 25_000;
    let blob: String = {
        // Round-robin over the warm set so every request is a cache hit.
        let mut s = String::with_capacity(PER_CONN * 32);
        for i in 0..PER_CONN {
            s.push_str(&warm_set[i % warm_set.len()]);
            s.push('\n');
        }
        s
    };
    let blob = Arc::new(blob);
    let t0 = Instant::now();
    thread::scope(|scope| {
        for _ in 0..CONNS {
            let blob = Arc::clone(&blob);
            scope.spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                let write_half = stream.try_clone().expect("clone");
                // Writer thread streams the whole blob; reader drains replies
                // concurrently so neither side blocks on a full socket buffer.
                let writer = thread::spawn(move || {
                    let mut w = BufWriter::new(write_half);
                    w.write_all(blob.as_bytes()).expect("send blob");
                    w.flush().expect("flush blob");
                });
                let mut reader = BufReader::new(stream);
                for _ in 0..PER_CONN {
                    let r = read_reply(&mut reader).expect("framed").expect("reply");
                    assert!(r.ok, "warm query failed: {}", r.head);
                }
                writer.join().expect("writer thread");
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let total = (CONNS * PER_CONN) as f64;
    let qps = total / secs;

    println!("serve-throughput-requests: {}", CONNS * PER_CONN);
    println!("serve-throughput-secs: {secs:.3}");
    println!("serve-throughput-qps: {qps:.0}");
    let (req, err, hits, misses, lat_ns, max_ns) =
        server.metrics().endpoint_snapshot(Endpoint::Query);
    println!("serve-query-requests: {req}");
    println!("serve-query-errors: {err}");
    println!("serve-cache-hits: {hits}");
    println!("serve-cache-misses: {misses}");
    println!("serve-query-avg-latency-us: {:.1}", lat_ns as f64 / req.max(1) as f64 / 1e3);
    println!("serve-query-max-latency-us: {:.1}", max_ns as f64 / 1e3);
    println!("serve-duplicate-runs: {}", engine.duplicate_runs());

    if qps < 100_000.0 {
        eprintln!("FAIL: warm throughput {qps:.0} qps is below the 100k qps gate");
        failed = true;
    }
    if engine.sim_runs() != warm_sim_runs {
        eprintln!(
            "FAIL: the warm throughput phase ran the simulator {} extra times (must be 0)",
            engine.sim_runs() - warm_sim_runs
        );
        failed = true;
    }
    if engine.duplicate_runs() != 0 {
        eprintln!("FAIL: duplicate simulator runs detected (single-flight broken)");
        failed = true;
    }
    if warm_sim_runs > 17 {
        eprintln!(
            "FAIL: warming a 16-point set + 1 cold point issued {warm_sim_runs} simulator \
             runs (must be <= 17)"
        );
        failed = true;
    }

    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
