//! `cargo bench --bench sim_hotpath` — L3 hot-path throughput: simulated
//! core-cycles per wall-clock second for each benchmark kernel. This is the
//! §Perf gate of EXPERIMENTS.md: the full DSE (18×8×2) must complete in
//! seconds, which requires ≥20 M simulated core-cycles/s on the production
//! (event-driven) engine.
//!
//! Both issue engines are timed on identical workloads: the per-cycle
//! `reference` loop is the pre-optimization baseline, the `event` engine is
//! the production hot path. The final lines print the aggregate throughput
//! of each plus the speedup — CI lifts them into the job summary, and the
//! EXPERIMENTS.md §Perf table is regenerated from them.

use std::time::Instant;

use transpfp::cluster::{Cluster, Engine};
use transpfp::config::ClusterConfig;
use transpfp::kernels::{Benchmark, Variant};
use transpfp::trace::TraceConfig;

fn main() {
    let cfg = ClusterConfig::new(16, 8, 1);
    let reps = 3;
    let mut grand = [0.0f64; 2]; // [event, reference] wall seconds
    let mut grand_traced = 0.0f64; // event engine, tracer attached
    let mut grand_cycles = 0u64;
    println!("simulator hot-path throughput on {} ({} cores):", cfg, cfg.cores);
    for b in Benchmark::all() {
        for v in [Variant::Scalar, Variant::VEC] {
            let w = b.build(v, &cfg);
            // One cluster per workload, reset between repetitions: the
            // TCDM/L2/I$/decoded-program allocations are reused.
            let mut cl = Cluster::new(cfg, w.program.clone());
            let mut cycles = 0u64;
            let mut secs = [0.0f64; 2];
            for (ei, engine) in [Engine::Event, Engine::Reference].into_iter().enumerate() {
                let _ = w.run_in_with(&mut cl, cfg.cores, engine); // warm-up
                // Runs are deterministic, so best-of-reps wall time is the
                // noise-robust estimator (scaled back to reps for the sums).
                let mut best = f64::INFINITY;
                let mut c = 0u64;
                for _ in 0..reps {
                    let t0 = Instant::now();
                    let (stats, _) = w.run_in_with(&mut cl, cfg.cores, engine).unwrap();
                    best = best.min(t0.elapsed().as_secs_f64());
                    c += stats.total_cycles * cfg.cores as u64;
                }
                secs[ei] = best * reps as f64;
                cycles = c; // identical across engines (differentially tested)
            }
            // Tracing-enabled pass on the event engine: same cluster with a
            // tracer attached (the ring buffers are reused across reps via
            // reset()). The disabled passes above already time the exact
            // code the gate protects — a tracer-less cluster.
            cl.attach_tracer(TraceConfig::default());
            let _ = w.run_in_with(&mut cl, cfg.cores, Engine::Event); // warm-up
            let mut best_traced = f64::INFINITY;
            for _ in 0..reps {
                let t0 = Instant::now();
                let _ = w.run_in_with(&mut cl, cfg.cores, Engine::Event).unwrap();
                best_traced = best_traced.min(t0.elapsed().as_secs_f64());
            }
            grand_traced += best_traced * reps as f64;
            grand[0] += secs[0];
            grand[1] += secs[1];
            grand_cycles += cycles;
            println!(
                "  {:8} {:7}  event {:>8.1} M core-cycles/s  reference {:>7.1} M  ({} cycles/run)",
                b.name(),
                v.label(),
                cycles as f64 / secs[0] / 1e6,
                cycles as f64 / secs[1] / 1e6,
                cycles / reps / cfg.cores as u64
            );
        }
    }
    let event_mcps = grand_cycles as f64 / grand[0] / 1e6;
    let reference_mcps = grand_cycles as f64 / grand[1] / 1e6;
    println!(
        "aggregate: {:.1} M simulated core-cycles/s (event engine) over {:.2}s",
        event_mcps, grand[0]
    );
    println!(
        "aggregate-reference: {:.1} M simulated core-cycles/s over {:.2}s",
        reference_mcps, grand[1]
    );
    let speedup = event_mcps / reference_mcps;
    println!("speedup: {speedup:.2}x event vs reference (gates: >=2.0x, event >=20 M core-cycles/s)");
    // Trace overhead (EXPERIMENTS.md §Trace): the disabled path is the
    // event timing above — it must hold the absolute ≥20 M core-cycles/s
    // floor, which bounds any disabled-path regression. Enabled tracing
    // (default 64 Ki-record rings) may cost at most 2× the disabled path.
    let traced_mcps = grand_cycles as f64 / grand_traced / 1e6;
    let trace_ratio = grand_traced / grand[0];
    println!("trace-disabled: {event_mcps:.1} M simulated core-cycles/s (tracer detached)");
    println!(
        "trace-enabled: {traced_mcps:.1} M simulated core-cycles/s ({trace_ratio:.2}x \
         disabled wall time; gate: <=2.0x)"
    );
    let mut failed = false;
    if event_mcps < 20.0 {
        eprintln!("GATE FAILED: event engine below 20 M core-cycles/s ({event_mcps:.1} M)");
        failed = true;
    }
    if speedup < 2.0 {
        eprintln!("GATE FAILED: event engine under 2.0x the reference engine ({speedup:.2}x)");
        failed = true;
    }
    if trace_ratio > 2.0 {
        eprintln!(
            "GATE FAILED: tracing-enabled runs cost over 2x the disabled path ({trace_ratio:.2}x)"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
