//! `cargo bench --bench sim_hotpath` — L3 hot-path throughput: simulated
//! core-cycles per wall-clock second for each benchmark kernel. This is the
//! §Perf gate of EXPERIMENTS.md: the full DSE (18×8×2) must complete in
//! seconds, which requires ≥20 M simulated core-cycles/s.

use std::time::Instant;

use transpfp::config::ClusterConfig;
use transpfp::kernels::{Benchmark, Variant};

fn main() {
    let cfg = ClusterConfig::new(16, 8, 1);
    let mut grand_cycles = 0u64;
    let t_all = Instant::now();
    println!("simulator hot-path throughput on {} ({} cores):", cfg, cfg.cores);
    for b in Benchmark::all() {
        for v in [Variant::Scalar, Variant::VEC] {
            let w = b.build(v, &cfg);
            // Warm-up + 3 measured repetitions.
            let _ = w.run(&cfg);
            let reps = 3;
            let t0 = Instant::now();
            let mut cycles = 0u64;
            for _ in 0..reps {
                let (stats, _) = w.run(&cfg);
                cycles += stats.total_cycles * cfg.cores as u64;
            }
            let dt = t0.elapsed().as_secs_f64();
            grand_cycles += cycles;
            println!(
                "  {:8} {:7}  {:>8.1} M core-cycles/s  ({} cycles/run)",
                b.name(),
                v.label(),
                cycles as f64 / dt / 1e6,
                cycles / reps / cfg.cores as u64
            );
        }
    }
    let dt = t_all.elapsed().as_secs_f64();
    println!(
        "aggregate: {:.1} M simulated core-cycles/s over {:.2}s",
        grand_cycles as f64 / dt / 1e6,
        dt
    );
}
