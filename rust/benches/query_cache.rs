//! `cargo bench --bench query_cache` — gates the measurement cache.
//!
//! Regenerates Table 4 twice on a private query engine: the cold pass
//! simulates all 144 points (9 eight-core configs × 8 benchmarks × 2
//! variants); the warm pass must resolve entirely from the cache. Gates
//! (process exits non-zero on violation):
//!
//! * the warm pass issues **zero** simulator runs (cache-stats assertion);
//! * warm resolves ≥ 10× faster than cold;
//! * the warm table is byte-identical to the cold one.
//!
//! The `cache-*` lines below are grepped into the CI step summary.

use std::process::ExitCode;
use std::time::Instant;

use transpfp::coordinator::{table45, QueryEngine};

const TABLE4_POINTS: u64 = 144;
const MIN_SPEEDUP: f64 = 10.0;

fn main() -> ExitCode {
    let engine = QueryEngine::new();

    let t0 = Instant::now();
    let cold = table45(&engine, 8).expect("cold table4 sweep completes");
    let cold_s = t0.elapsed().as_secs_f64();
    let after_cold = engine.stats();

    let t1 = Instant::now();
    let warm = table45(&engine, 8).expect("warm table4 sweep completes");
    let warm_s = t1.elapsed().as_secs_f64();
    let after_warm = engine.stats();

    let warm_misses = after_warm.misses - after_cold.misses;
    let warm_hits = after_warm.hits - after_cold.hits;
    let speedup = cold_s / warm_s.max(1e-9);

    println!("cache-cold-seconds: {cold_s:.3}");
    println!("cache-warm-seconds: {warm_s:.6}");
    println!("cache-speedup: {speedup:.0}x");
    println!("cache-cold-misses: {}", after_cold.misses);
    println!("cache-warm-hits: {warm_hits}");
    println!("cache-warm-misses: {warm_misses}");
    println!("cache-entries: {}", after_warm.entries);

    let mut ok = true;
    if after_cold.misses != TABLE4_POINTS || after_cold.hits != 0 {
        eprintln!(
            "FAIL: cold table4 should miss exactly {TABLE4_POINTS} unique points, saw {} misses / {} hits",
            after_cold.misses, after_cold.hits
        );
        ok = false;
    }
    if warm_misses != 0 {
        eprintln!("FAIL: warm-cache table4 issued {warm_misses} simulator runs (must be 0)");
        ok = false;
    }
    if warm_hits != TABLE4_POINTS {
        eprintln!("FAIL: warm table4 expected {TABLE4_POINTS} cache hits, saw {warm_hits}");
        ok = false;
    }
    if warm.to_csv() != cold.to_csv() {
        eprintln!("FAIL: warm table diverges from cold table");
        ok = false;
    }
    if speedup < MIN_SPEEDUP {
        eprintln!("FAIL: warm-vs-cold speedup {speedup:.1}x below the {MIN_SPEEDUP}x gate");
        ok = false;
    }
    if !ok {
        return ExitCode::FAILURE;
    }
    println!("query_cache: OK (zero warm misses, {speedup:.0}x >= {MIN_SPEEDUP}x)");
    ExitCode::SUCCESS
}
