//! `cargo bench --bench runtime` — gates the runtime-era figure pipeline.
//!
//! Regenerates fig 5 (power @100 MHz, full design space) and fig 6
//! (parallel + vectorization speed-ups, 16-core configs × 5 occupancies ×
//! 2 variants) twice on a private query engine: the cold pass simulates
//! every unique point; the warm pass must resolve entirely from the cache
//! — occupancy is part of the address since ENGINE_VERSION 3. Gates
//! (process exits non-zero on violation):
//!
//! * the warm pass issues **zero** simulator runs (cache-stats assertion);
//! * warm resolves ≥ 10× faster than cold;
//! * the warm tables are byte-identical to the cold ones.
//!
//! The `runtime-*` lines below are grepped into the CI step summary.

use std::process::ExitCode;
use std::time::Instant;

use transpfp::coordinator::{fig5, fig6, QueryEngine};

/// fig5: 18 configs × MATMUL scalar at full occupancy. fig6: 9 16-core
/// configs × 8 benches × 5 occupancies × 2 variants. The 9 16-core
/// full-occupancy MATMUL-scalar points appear in both figures and resolve
/// from the cache the second time they are planned.
const UNIQUE_POINTS: u64 = 18 + 9 * 8 * 5 * 2 - 9;
const MIN_SPEEDUP: f64 = 10.0;

fn main() -> ExitCode {
    let engine = QueryEngine::new();

    let t0 = Instant::now();
    let cold5 = fig5(&engine).expect("cold fig5 sweep completes");
    let cold6 = fig6(&engine).expect("cold fig6 sweep completes");
    let cold_s = t0.elapsed().as_secs_f64();
    let after_cold = engine.stats();

    let t1 = Instant::now();
    let warm5 = fig5(&engine).expect("warm fig5 sweep completes");
    let warm6 = fig6(&engine).expect("warm fig6 sweep completes");
    let warm_s = t1.elapsed().as_secs_f64();
    let after_warm = engine.stats();

    let warm_misses = after_warm.misses - after_cold.misses;
    let speedup = cold_s / warm_s.max(1e-9);

    println!("runtime-cold-seconds: {cold_s:.3}");
    println!("runtime-warm-seconds: {warm_s:.6}");
    println!("runtime-speedup: {speedup:.0}x");
    println!("runtime-cold-misses: {}", after_cold.misses);
    println!("runtime-warm-misses: {warm_misses}");
    println!("runtime-entries: {}", after_warm.entries);

    let mut ok = true;
    if after_cold.misses != UNIQUE_POINTS {
        eprintln!(
            "FAIL: cold fig5+fig6 should miss exactly {UNIQUE_POINTS} unique points, saw {}",
            after_cold.misses
        );
        ok = false;
    }
    if warm_misses != 0 {
        eprintln!("FAIL: warm-cache fig5/fig6 issued {warm_misses} simulator runs (must be 0)");
        ok = false;
    }
    if warm5.to_csv() != cold5.to_csv() {
        eprintln!("FAIL: warm fig5 diverges from cold fig5");
        ok = false;
    }
    if warm6.to_csv() != cold6.to_csv() {
        eprintln!("FAIL: warm fig6 diverges from cold fig6");
        ok = false;
    }
    if speedup < MIN_SPEEDUP {
        eprintln!("FAIL: warm-vs-cold speedup {speedup:.1}x below the {MIN_SPEEDUP}x gate");
        ok = false;
    }
    if !ok {
        return ExitCode::FAILURE;
    }
    println!("runtime: OK (zero warm misses, {speedup:.0}x >= {MIN_SPEEDUP}x)");
    ExitCode::SUCCESS
}
