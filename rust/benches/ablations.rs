//! `cargo bench --bench ablations` — design-choice ablations called out in
//! DESIGN.md:
//!
//! 1. **Interleaved vs blocked FPU mapping** (§3.2): the paper claims the
//!    interleaved allocation avoids contention "when the number of workers
//!    in parallel sections is smaller than the number of cores" with ≤1%
//!    overhead vs a full crossbar. We compare both mappings at full and
//!    half occupancy.
//! 2. **Shared-I$ cold misses**: cost of the cold-fill model vs a perfect
//!    cache (bounds the I$ contribution to the Table 4/5 numbers).
//! 3. **float16 vs bfloat16 vectors** (§5.2): "no significant difference in
//!    execution time" — verified cycle-exactly.
//! 4. **DIV-SQRT sharing**: KMEANS (the fdiv-using benchmark) with the
//!    cluster-shared iterative unit — contention visibility.

use transpfp::cluster::Cluster;
use transpfp::config::ClusterConfig;
use transpfp::kernels::{Benchmark, Variant};
use transpfp::transfp::FpMode;

fn main() {
    // --- 1. FPU mapping, full vs half occupancy.
    println!("=== ablation 1: interleaved vs blocked FPU mapping (8c4f1p, MATMUL scalar) ===");
    for workers in [8usize, 4] {
        let mut row = format!("  {workers} workers:");
        for (label, cfg) in [
            ("interleaved", ClusterConfig::new(8, 4, 1)),
            ("blocked", ClusterConfig::new(8, 4, 1).with_blocked_fpu_map()),
        ] {
            let w = Benchmark::Matmul.build(Variant::Scalar, &cfg);
            let (stats, out) = w.run_on(&cfg, workers).unwrap();
            w.verify(&out).unwrap();
            let cont: u64 = stats.per_core.iter().map(|c| c.fpu_cont).sum();
            row.push_str(&format!(
                "  {label}: {} cycles ({} fpu-contention)",
                stats.total_cycles, cont
            ));
        }
        println!("{row}");
    }
    println!("  (interleaving must win at half occupancy — §3.2)\n");

    // --- 2. I$ cold misses.
    println!("=== ablation 2: shared-I$ cold-fill vs perfect cache (16c8f1p) ===");
    for b in [Benchmark::Fir, Benchmark::Fft] {
        let cfg = ClusterConfig::new(16, 8, 1);
        let w = b.build(Variant::Scalar, &cfg);
        let real = {
            let mut cl = Cluster::new(cfg, w.program.clone());
            w.stage_into(&mut cl.mem);
            cl.run().unwrap().total_cycles
        };
        let perfect = {
            let mut cl = Cluster::new(cfg, w.program.clone());
            cl.perfect_icache = true;
            w.stage_into(&mut cl.mem);
            cl.run().unwrap().total_cycles
        };
        println!(
            "  {:8} cold-fill {} vs perfect {} (+{:.2}%)",
            b.name(),
            real,
            perfect,
            (real as f64 / perfect as f64 - 1.0) * 100.0
        );
    }
    println!();

    // --- 3. float16 vs bfloat16.
    println!("=== ablation 3: float16 vs bfloat16 vector cycle counts (8c8f1p) ===");
    let cfg = ClusterConfig::new(8, 8, 1);
    for b in Benchmark::all() {
        let f16 = b.build(Variant::Vector(FpMode::VecF16), &cfg);
        let bf16 = b.build(Variant::Vector(FpMode::VecBf16), &cfg);
        let (s16, o16) = f16.run(&cfg).unwrap();
        let (sbf, obf) = bf16.run(&cfg).unwrap();
        f16.verify(&o16).unwrap();
        bf16.verify(&obf).unwrap();
        let delta = (s16.total_cycles as f64 / sbf.total_cycles as f64 - 1.0) * 100.0;
        println!(
            "  {:8} f16 {:>7}  bf16 {:>7}  Δ {:+.2}% {}",
            b.name(),
            s16.total_cycles,
            sbf.total_cycles,
            delta,
            if delta.abs() < 1.0 { "≈ (paper: single value for both)" } else { "" }
        );
    }
    println!();

    // --- 4. DIV-SQRT contention visibility.
    println!("=== ablation 4: shared DIV-SQRT contention (KMEANS scalar) ===");
    for cores in [8usize, 16] {
        let cfg = ClusterConfig::new(cores, cores, 1);
        let w = Benchmark::Kmeans.build(Variant::Scalar, &cfg);
        let mut cl = Cluster::new(cfg, w.program.clone());
        w.stage_into(&mut cl.mem);
        let stats = cl.run().unwrap();
        let cont: u64 = stats.per_core.iter().map(|c| c.divsqrt_cont).sum();
        println!(
            "  {cores} cores: {} fdiv ops through one shared unit, {} contention cycles",
            cl.fpus.divsqrt_ops, cont
        );
    }
}
