//! `cargo bench --bench figures` — regenerates Figs 3–8 of the paper:
//! frequency spreads, area, power, parallel/vector speed-ups, sharing-factor
//! and pipelining trends.

use std::time::Instant;

use transpfp::coordinator::QueryEngine;

fn timed<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let r = f();
    eprintln!("[bench] {name}: {:.2}s", t0.elapsed().as_secs_f64());
    r
}

fn main() {
    println!("================ Fig 3 — fmax min/median/max across FPU counts ================");
    println!("{}", timed("fig3", transpfp::coordinator::fig3).render());

    println!("================ Fig 4 — total area per configuration ================");
    println!("{}", timed("fig4", transpfp::coordinator::fig4).render());

    println!("================ Fig 5 — power @100 MHz per configuration (f32 MATMUL) ================");
    let t = timed("fig5", || transpfp::coordinator::fig5(QueryEngine::global()))
        .expect("fig5 sweep completes");
    println!("{}", t.render());

    println!("================ Fig 6 — parallel + vectorization speed-ups (16-core) ================");
    let t = timed("fig6", || transpfp::coordinator::fig6(QueryEngine::global()))
        .expect("fig6 sweep completes");
    println!("{}", t.render());

    println!("================ Fig 7 — normalized metrics vs sharing factor (1 stage) ================");
    let t = timed("fig7", || transpfp::coordinator::fig7(QueryEngine::global()))
        .expect("fig7 sweep completes");
    println!("{}", t.render());

    println!("================ Fig 8 — normalized metrics vs pipeline stages (1/1) ================");
    let t = timed("fig8", || transpfp::coordinator::fig8(QueryEngine::global()))
        .expect("fig8 sweep completes");
    println!("{}", t.render());
}
