//! Design-space exploration: resolve all 18 Table 2 configurations over the
//! full benchmark suite through the memoizing query engine, report the best
//! configuration per metric — the paper's §5.3 headline analysis
//! ("16c16f1p best performance, 16c16f0p most energy-efficient, 8c4f1p most
//! area-efficient") — and extract the Pareto frontier over
//! (Gflop/s, Gflop/s/W, Gflop/s/mm²).
//!
//! ```sh
//! cargo run --release --example dse_sweep
//! ```

use transpfp::coordinator::pareto_table_from;
use transpfp::prelude::{points, Benchmark, ClusterConfig, QueryEngine, Variant};

fn main() {
    let engine = QueryEngine::new();
    let pts = points(
        &ClusterConfig::design_space(),
        &Benchmark::all(),
        &[Variant::Scalar, Variant::VEC],
    );
    eprintln!("resolving {} design-space points (cold cache) …", pts.len());
    let t0 = std::time::Instant::now();
    let ms = engine.query(&pts).expect("design-space points resolve");
    let dt = t0.elapsed();
    let total_cycles: u64 = ms.iter().map(|m| m.cycles).sum();
    let cold = engine.stats();
    eprintln!(
        "{} runs, {:.1} M simulated cycles in {:.2}s ({:.1} Mcycles/s); cache: {} misses",
        ms.len(),
        total_cycles as f64 / 1e6,
        dt.as_secs_f64(),
        total_cycles as f64 / 1e6 / dt.as_secs_f64(),
        cold.misses,
    );

    // Same batch again: the planner resolves everything from the cache.
    let t1 = std::time::Instant::now();
    let warm_ms = engine.query(&pts).expect("warm re-query resolves");
    let warm = engine.stats();
    eprintln!(
        "warm re-query: {} points in {:.4}s, {} new simulator runs\n",
        warm_ms.len(),
        t1.elapsed().as_secs_f64(),
        warm.misses - cold.misses,
    );
    assert_eq!(warm.misses, cold.misses, "warm re-query must not simulate");

    assert!(ms.iter().all(|m| m.verified), "all runs must verify numerically");

    // Best config per metric, averaged over the suite (vector variant, like
    // the paper's peak numbers; scalar shown for reference).
    for variant in [Variant::Scalar, Variant::VEC] {
        println!("=== {} variants ===", variant.label());
        let mut per_cfg: std::collections::BTreeMap<String, (f64, f64, f64, u32)> =
            Default::default();
        for m in ms.iter().filter(|m| m.variant.label() == variant.label()) {
            let e = per_cfg.entry(m.cfg.mnemonic()).or_insert((0.0, 0.0, 0.0, 0));
            e.0 += m.metrics.perf_gflops;
            e.1 += m.metrics.energy_eff;
            e.2 += m.metrics.area_eff;
            e.3 += 1;
        }
        let best = |idx: usize| -> (String, f64) {
            per_cfg
                .iter()
                .map(|(k, v)| {
                    let avg = [v.0, v.1, v.2][idx] / v.3 as f64;
                    (k.clone(), avg)
                })
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
        };
        let (bp, vp) = best(0);
        let (be, ve) = best(1);
        let (ba, va) = best(2);
        println!("  best performance      : {bp}  ({vp:.2} Gflop/s avg)");
        println!("  best energy efficiency: {be}  ({ve:.0} Gflop/s/W avg)");
        println!("  best area efficiency  : {ba}  ({va:.2} Gflop/s/mm² avg)");
        // Peak numbers across individual benchmarks (the abstract's figures).
        let peak_perf = ms
            .iter()
            .filter(|m| m.variant.label() == variant.label())
            .max_by(|a, b| a.metrics.perf_gflops.partial_cmp(&b.metrics.perf_gflops).unwrap())
            .unwrap();
        let peak_eff = ms
            .iter()
            .filter(|m| m.variant.label() == variant.label())
            .max_by(|a, b| a.metrics.energy_eff.partial_cmp(&b.metrics.energy_eff).unwrap())
            .unwrap();
        println!(
            "  peak perf {:.2} Gflop/s ({} on {});  peak eff {:.0} Gflop/s/W ({} on {})\n",
            peak_perf.metrics.perf_gflops,
            peak_perf.bench.name(),
            peak_perf.cfg.mnemonic(),
            peak_eff.metrics.energy_eff,
            peak_eff.bench.name(),
            peak_eff.cfg.mnemonic()
        );
    }

    println!("=== Pareto frontier (perf, e.eff, a.eff — all maximized) ===");
    print!("{}", pareto_table_from(&ms).render());
    println!();
    println!("paper: best perf 16c16f1p (5.92 Gflop/s, FIR vector); best energy");
    println!("       16c16f0p (167 Gflop/s/W); best area 8c4f1p (3.5 Gflop/s/mm²)");
}
