use transpfp::config::ClusterConfig;
use transpfp::coordinator::run_one;
use transpfp::kernels::{Benchmark, Variant};
fn main() {
    for (mn, b, v, paper) in [
        ("16c16f0p", Benchmark::Fir, Variant::VEC, 167.0),
        ("16c16f0p", Benchmark::Fir, Variant::Scalar, 99.0),
        ("8c8f0p", Benchmark::Fir, Variant::VEC, 162.0),
        ("8c8f0p", Benchmark::Fir, Variant::Scalar, 97.0),
        ("16c16f0p", Benchmark::Matmul, Variant::Scalar, 80.0),
    ] {
        let cfg = ClusterConfig::parse(mn).unwrap();
        let m = run_one(&cfg, b, v).unwrap();
        println!("{mn} {} {}: E.EFF {:.1} (paper {paper}) PERF {:.2} fpc {:.2}", b.name(), v.label(), m.metrics.energy_eff, m.metrics.perf_gflops, m.metrics.flops_per_cycle);
    }
    // perf anchors
    for (mn, paper) in [("16c16f1p", 5.92), ("8c8f1p", 3.57)] {
        let cfg = ClusterConfig::parse(mn).unwrap();
        let m = run_one(&cfg, Benchmark::Fir, Variant::VEC).unwrap();
        println!("{mn} FIR vec PERF {:.2} (paper {paper})", m.metrics.perf_gflops);
    }
    let m = run_one(&ClusterConfig::parse("16c16f1p").unwrap(), Benchmark::Matmul, Variant::Scalar).unwrap();
    println!("16c16f1p MATMUL scalar PERF {:.2} (paper 2.86) E.EFF {:.1}", m.metrics.perf_gflops, m.metrics.energy_eff);
}
