//! Quickstart: run one benchmark on one cluster configuration and print the
//! paper's three metrics plus the performance-counter breakdown.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use transpfp::config::Corner;
use transpfp::coordinator::run_one;
use transpfp::model;
use transpfp::prelude::{Benchmark, ClusterConfig, Variant};

fn main() {
    // The paper's best-area-efficiency configuration (Table 6).
    let cfg = ClusterConfig::new(8, 4, 1);
    println!("cluster {} — {} cores, {} shared FPnew FPUs, {} pipeline stage(s)", cfg, cfg.cores, cfg.fpus, cfg.pipe);
    println!(
        "fmax {} MHz (0.8 V ST) / {} MHz (0.65 V NT), area {:.2} mm²\n",
        model::fmax_mhz(&cfg, Corner::St).round(),
        model::fmax_mhz(&cfg, Corner::Nt).round(),
        model::area_mm2(&cfg)
    );

    for variant in [Variant::Scalar, Variant::VEC] {
        let m = run_one(&cfg, Benchmark::Matmul, variant).expect("benchmark terminates");
        assert!(m.verified, "numeric verification failed");
        println!("MATMUL {:7}: {:>8} cycles  {:.2} Gflop/s  {:.0} Gflop/s/W  {:.2} Gflop/s/mm²",
            variant.label(), m.cycles, m.metrics.perf_gflops, m.metrics.energy_eff, m.metrics.area_eff);
        println!(
            "  stalls: fpu-contention {}  fpu-latency {}  tcdm-contention {}  wb {}  i$ {}  barrier {}",
            m.agg.fpu_cont, m.agg.fpu_stall, m.agg.tcdm_cont, m.agg.wb_stall,
            m.agg.icache_stall, m.agg.barrier_idle
        );
    }
    println!("\n(vectorization gain comes from the packed-SIMD 2×16-bit datapath");
    println!(" with expanding dot products — §5.3.1 of the paper)");
}
