//! End-to-end near-sensor driver: an ExG biosignal window flows through the
//! paper's motivating pipeline — FIR band-pass → DWT feature extraction →
//! SVM classification — each stage offloaded to the simulated transprecision
//! cluster (the host stages data between offloads via the cluster DMA, the
//! standard PULP execution model). Reports per-window latency, throughput
//! and energy at the edge configuration, then cross-checks every stage
//! against the AOT-compiled XLA goldens and runs the Pallas-kernel MLP
//! (`exg_mlp.hlo.txt`) on the extracted features.
//!
//! ```sh
//! make artifacts && cargo run --release --example biosignal_pipeline
//! ```

use transpfp::cluster::mem::{Dma, Memory, L2_BASE, TCDM_BASE};
use transpfp::config::{ClusterConfig, Corner};
use transpfp::kernels::{Benchmark, Variant};
use transpfp::model::{self, Activity};
use transpfp::runtime::Golden;

fn main() {
    let cfg = ClusterConfig::new(8, 4, 1); // best-area-efficiency edge config
    let f_nt = model::fmax_mhz(&cfg, Corner::Nt);
    println!("ExG pipeline on {} @ {} MHz (0.65 V near-threshold)\n", cfg, f_nt.round());

    // --- model the DMA staging of one 512-sample window from L2.
    let mut mem = Memory::new(&cfg);
    let mut dma = Dma::default();
    let window: Vec<f32> = (0..512)
        .map(|i| {
            let t = i as f32 / 256.0;
            (6.283 * 10.0 * t).sin() * 0.4 + (6.283 * 49.0 * t).sin() * 0.1
        })
        .collect();
    mem.write_f32_slice(L2_BASE, &window);
    let dma_done = dma.transfer(&mut mem, 0, L2_BASE, TCDM_BASE, 512);
    println!("DMA window staging: {dma_done} cycles (512 words from L2)");

    // --- run the three offloads on the cluster simulator.
    let mut total_cycles = dma_done;
    let mut total_energy_pj = 0.0;
    let mut flops = 0u64;
    for (stage, bench) in
        [("FIR band-pass", Benchmark::Fir), ("DWT features", Benchmark::Dwt), ("SVM classify", Benchmark::Svm)]
    {
        let w = bench.build(Variant::Scalar, &cfg);
        let (stats, out) = w.run(&cfg).expect("pipeline stage terminates");
        w.verify(&out).expect("stage must verify");
        let act = Activity::from_stats(&stats);
        let epc = model::energy_per_cycle_pj(&cfg, Corner::Nt, &act);
        let energy = epc * stats.total_cycles as f64;
        total_cycles += stats.total_cycles;
        total_energy_pj += energy;
        flops += stats.flops();
        println!(
            "{stage:16}: {:>7} cycles  {:>6} flops  {:.1} nJ",
            stats.total_cycles,
            stats.flops(),
            energy / 1000.0
        );
        if bench == Benchmark::Svm {
            println!("                  decision: class {:+.0} (score {:.3})", out[1], out[0]);
        }
    }

    let latency_us = total_cycles as f64 / f_nt;
    let energy_uj = total_energy_pj / 1e6;
    println!("\nper-window: {total_cycles} cycles = {latency_us:.1} µs → {:.0} windows/s", 1e6 / latency_us);
    println!(
        "energy: {energy_uj:.2} µJ/window  ({:.1} Gflop/s/W pipeline average)",
        1000.0 * flops as f64 / total_energy_pj
    );
    println!("paper headline: up to 97 (scalar) / 162 (vector) Gflop/s/W on the 8-core cluster\n");

    // --- cross-check each stage against the XLA goldens + run the MLP.
    if !std::path::Path::new("artifacts/MANIFEST").exists() {
        println!("artifacts/ missing — run `make artifacts` for the XLA cross-check");
        return;
    }
    match transpfp::runtime::validate_all("artifacts") {
        Ok(_) => println!("XLA cross-check: all stages match the AOT goldens ✓"),
        Err(e) => {
            eprintln!("XLA cross-check failed: {e}");
            std::process::exit(1);
        }
    }

    // MLP classifier on 16 DWT-feature windows through the Pallas kernel
    // (bfloat16 operands, f32 accumulation — the transprecision contract).
    let g = Golden::load("artifacts", "exg_mlp").expect("exg_mlp artifact");
    let feats: Vec<f32> = (0..16 * 64).map(|i| ((i * 7 % 23) as f32 - 11.0) / 23.0).collect();
    let w1: Vec<f32> = (0..64 * 64).map(|i| ((i * 13 % 31) as f32 - 15.0) / 120.0).collect();
    let w2: Vec<f32> = (0..64 * 16).map(|i| ((i * 11 % 29) as f32 - 14.0) / 110.0).collect();
    let out = g
        .run_f32(&[(feats, vec![16, 64]), (w1, vec![64, 64]), (w2, vec![64, 16])])
        .expect("exg_mlp execution");
    let logits = &out[0];
    print!("Pallas-MLP classes for 16 windows: ");
    for w in 0..16 {
        let row = &logits[w * 16..(w + 1) * 16];
        let cls = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        print!("{cls} ");
    }
    println!("\n\ne2e OK: 3-stage sim pipeline + PJRT-executed Pallas MLP, all XLA-validated");
}
