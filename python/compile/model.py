"""L2 JAX golden models — build-time only, never on the request path.

One dtype-parametric golden per benchmark of Table 3, plus a small
end-to-end near-sensor classifier that calls the L1 Pallas kernel
(`kernels.matmul_tp`) so the kernel lowers into the exported HLO.

Contract with the Rust runtime (`rust/src/runtime/`): every exported
function takes binary32 arrays (16-bit quantization happens *inside* the
graph, on the same RNE lattice as the simulator's `transfp`), returns a
tuple of binary32 arrays, and its parameter order matches the order of the
benchmark's staged, non-scratch TCDM buffers (see `aot.py::EXPORTS`).

Constant tables (DWT filter bank, IIR biquad) are bit-identical to the Rust
kernels' constants (`rust/src/kernels/{dwt,iir}.rs`).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .kernels.matmul_tp import matmul_tp

# --------------------------------------------------------------- constants

# db2 filter bank — must match rust/src/kernels/dwt.rs::filters().
DWT_H = np.array([0.4829629, 0.8365163, 0.22414387, -0.12940952], np.float32)
DWT_G = np.array([DWT_H[3], -DWT_H[2], DWT_H[1], -DWT_H[0]], np.float32)
DWT_TAPS = 4

# Biquad — must match rust/src/kernels/iir.rs::{B, A}.
IIR_B = np.array([0.2929, 0.5858, 0.2929], np.float32)
IIR_A = np.array([1.0, -0.34], np.float32)


# --------------------------------------------------------------- goldens

def matmul_f32(a, b):
    """C = A·B in binary32."""
    return (jnp.dot(a, b),)


def matmul_f16(a, b):
    """Transprecision matmul through the Pallas kernel (float16 operands,
    f32 accumulation), result quantized to float16 like the cluster's
    cast-and-pack output, returned widened to f32."""
    c = matmul_tp(a, b, dtype=jnp.float16, block=(16, 16, 16))
    return (c.astype(jnp.float16).astype(jnp.float32),)


def matmul_bf16(a, b):
    """Same with bfloat16 operands."""
    c = matmul_tp(a, b, dtype=jnp.bfloat16, block=(16, 16, 16))
    return (c.astype(jnp.bfloat16).astype(jnp.float32),)


def fir_f32(x, h):
    """y[i] = Σ_t h[t]·x[i+t] over the valid range (n = len(x) − len(h))."""
    n = x.shape[0] - h.shape[0]
    return (jnp.correlate(x, h, mode="valid")[:n],)


def fir_f16(x, h):
    """float16 operands, f32 accumulation, f16-quantized output."""
    n = x.shape[0] - h.shape[0]
    xq = x.astype(jnp.float16).astype(jnp.float32)
    hq = h.astype(jnp.float16).astype(jnp.float32)
    y = jnp.correlate(xq, hq, mode="valid")[:n]
    return (y.astype(jnp.float16).astype(jnp.float32),)


def conv_f32(img, k):
    """Valid 3×3 2D correlation (XLA convolution does not flip the kernel),
    flattened row-major like the simulator's output buffer."""
    h, w = img.shape
    out = jax.lax.conv(
        img[None, None, :, :], k[None, None, :, :], (1, 1), "VALID"
    )[0, 0]
    return (out.reshape(-1),)


def dwt_f32(x):
    """Multi-level db2 analysis with zero-extended edges; output layout
    [approx_L | detail_L | … | detail_1] (see rust/src/kernels/dwt.rs)."""
    levels = 3
    h = jnp.asarray(DWT_H)
    g = jnp.asarray(DWT_G)
    cur = x
    details = []
    for _ in range(levels):
        padded = jnp.pad(cur, (0, DWT_TAPS - 1))
        lo = jnp.correlate(padded, h, mode="valid")[::2]
        hi = jnp.correlate(padded, g, mode="valid")[::2]
        details.append(hi)
        cur = lo
    # [a_L, d_L, d_{L-1}, ..., d_1]
    return (jnp.concatenate([cur] + details[::-1]),)


def _bitrev_perm(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    perm = np.zeros(n, np.int32)
    for i in range(n):
        r = 0
        for b in range(bits):
            r |= ((i >> b) & 1) << (bits - 1 - b)
        perm[i] = r
    return perm


def fft_f32(x):
    """Radix-2 DIF FFT golden: interleaved (re, im) input of 2n values,
    output in the simulator's bit-reversed storage order."""
    n = x.shape[0] // 2
    z = x[0::2] + 1j * x[1::2]
    f = jnp.fft.fft(z)
    y = f[jnp.asarray(_bitrev_perm(n))]
    out = jnp.stack([jnp.real(y), jnp.imag(y)], axis=1).reshape(-1)
    return (out.astype(jnp.float32),)


def iir_f32(x):
    """Biquad: parallel feed-forward + scanned feedback recursion."""
    b0, b1, b2 = [jnp.float32(v) for v in IIR_B]
    a1, a2 = [jnp.float32(v) for v in IIR_A]
    xm1 = jnp.pad(x, (1, 0))[:-1]
    xm2 = jnp.pad(x, (2, 0))[:-2]
    w = b0 * x + b1 * xm1 + b2 * xm2

    def step(carry, wi):
        y1, y2 = carry
        y = wi + a1 * y1 + a2 * y2
        return (y, y1), y

    _, y = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), w)
    return (y,)


def kmeans_f32(pts, cent):
    """One Lloyd step: assign to the nearest centroid (squared distance,
    first-wins ties like the kernel's strict `<` argmin), then update; empty
    clusters keep their old centroid. Returns the k×d centroids flattened."""
    d2 = jnp.sum((pts[:, None, :] - cent[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=1)
    k = cent.shape[0]
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    counts = onehot.sum(axis=0)
    sums = onehot.T @ pts
    newc = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], cent)
    return (newc.reshape(-1),)


def svm_f32(sv, alpha, x, bias):
    """Linear SVM decision: [score, class]."""
    dots = sv @ x
    score = alpha @ dots + bias[0]
    cls = jnp.where(score >= 0.0, 1.0, -1.0)
    return (jnp.stack([score, cls]),)


# ---------------------------------------------------- end-to-end model

def exg_mlp(windows, w1, w2):
    """The near-sensor e2e model: a batch of 16 ExG feature windows (each 64
    DWT features) classified by a 2-layer MLP whose matmuls run on the
    transprecision Pallas kernel — 16-bit operands, f32 accumulation, the
    exact compute contract of the cluster's vector datapath.

    windows: [16, 64] f32; w1: [64, 64]; w2: [64, 16] → logits [16, 16].
    """
    h = matmul_tp(windows, w1, dtype=jnp.bfloat16, block=(16, 16, 16))
    h = jax.nn.relu(h)
    logits = matmul_tp(h, w2, dtype=jnp.bfloat16, block=(16, 16, 16))
    return (logits,)
