"""AOT compiler: lower every L2 golden + the e2e model to HLO **text**.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Run once via ``make artifacts``; the Rust binary is self-contained after.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

S = jax.ShapeDtypeStruct

# Default workload sizes — must match rust/src/kernels/mod.rs::Benchmark::build.
MATMUL_N = 32
FIR_N, FIR_TAPS = 512, 32
CONV_W, CONV_H = 32, 32
DWT_N = 512
FFT_N = 256
IIR_N = 512
KM_N, KM_D, KM_K = 256, 16, 4
SVM_NSV, SVM_D = 64, 32

F32 = jnp.float32

#: (artifact name, function, example arg shapes). Parameter order matches
#: the benchmark's staged non-scratch buffers (rust/src/runtime/mod.rs).
EXPORTS = [
    ("matmul_f32", model.matmul_f32, [S((MATMUL_N, MATMUL_N), F32)] * 2),
    ("matmul_f16", model.matmul_f16, [S((MATMUL_N, MATMUL_N), F32)] * 2),
    ("matmul_bf16", model.matmul_bf16, [S((MATMUL_N, MATMUL_N), F32)] * 2),
    ("fir_f32", model.fir_f32, [S((FIR_N + FIR_TAPS,), F32), S((FIR_TAPS,), F32)]),
    ("fir_f16", model.fir_f16, [S((FIR_N + FIR_TAPS,), F32), S((FIR_TAPS,), F32)]),
    ("conv_f32", model.conv_f32, [S((CONV_H, CONV_W), F32), S((3, 3), F32)]),
    ("dwt_f32", model.dwt_f32, [S((DWT_N,), F32)]),
    ("fft_f32", model.fft_f32, [S((2 * FFT_N,), F32)]),
    ("iir_f32", model.iir_f32, [S((IIR_N,), F32)]),
    ("kmeans_f32", model.kmeans_f32, [S((KM_N, KM_D), F32), S((KM_K, KM_D), F32)]),
    (
        "svm_f32",
        model.svm_f32,
        [S((SVM_NSV, SVM_D), F32), S((SVM_NSV,), F32), S((SVM_D,), F32), S((1,), F32)],
    ),
    ("exg_mlp", model.exg_mlp, [S((16, 64), F32), S((64, 64), F32), S((64, 16), F32)]),
]


def to_hlo_text(fn, args) -> str:
    """jit → lower → StableHLO → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default elides big constant tensors as
    # "{...}", which the rust-side HLO text parser would misparse — the
    # bit-reversal gather table of fft_f32 is exactly such a constant.
    return comp.as_hlo_text(True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="export a single artifact by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name, fn, shapes in EXPORTS:
        if args.only and name != args.only:
            continue
        text = to_hlo_text(fn, shapes)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shape_str = ";".join(
            "x".join(str(d) for d in s.shape) if s.shape else "scalar" for s in shapes
        )
        manifest.append(f"{name} {shape_str}")
        print(f"  {name}: {len(text)} chars → {path}")
    with open(os.path.join(args.out_dir, "MANIFEST"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
