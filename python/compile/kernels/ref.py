"""Pure-jnp correctness oracle for the L1 Pallas kernel.

The oracle expresses exactly the transprecision contract the kernel must
honour: quantize the binary32 operands to the 16-bit format, multiply with
binary32 accumulation, return binary32. pytest compares `matmul_tp` against
this under a hypothesis sweep of shapes, dtypes and value ranges.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_tp_ref(x: jax.Array, y: jax.Array, *, dtype=jnp.float16) -> jax.Array:
    """Reference: quantize → dot (f32 accumulate) → f32."""
    xq = x.astype(dtype)
    yq = y.astype(dtype)
    return jnp.dot(xq, yq, preferred_element_type=jnp.float32)


def quantize_roundtrip(x: jax.Array, dtype) -> jax.Array:
    """The value lattice the 16-bit format imposes (f32 → 16-bit → f32)."""
    return x.astype(dtype).astype(jnp.float32)
