"""L1 Pallas kernel: transprecision tiled matmul (16-bit operands, f32 acc).

This is the TPU re-expression of the paper's packed-SIMD insight
(DESIGN.md §Hardware-Adaptation): the cluster packs two 16-bit lanes into a
32-bit datapath and accumulates through the expanding dot product
(`vfdotpex.s.h`); on the MXU the same idea is 16-bit input tiles staged
through VMEM, multiplied on the systolic array, and accumulated in binary32
(`preferred_element_type=float32`). The cast-and-pack instructions map to
the convert ops at tile boundaries.

The kernel MUST be lowered with ``interpret=True``: real TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).

VMEM budget (documented for the DESIGN.md §Perf estimate): with the default
``block = (64, 64, 64)`` the working set per grid step is
64·64·2 B (A tile) + 64·64·2 B (B tile) + 64·64·4 B (f32 acc) ≈ 32 KiB —
far inside the ~16 MiB VMEM of a TPU core, leaving room for double
buffering; the MXU sees 64×64 bf16 tiles, its native shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile sizes (rows, cols, depth).
BLOCK_M = 64
BLOCK_N = 64
BLOCK_K = 64


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += x_tile @ y_tile, flushed at k end."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # 16-bit operand tiles, binary32 accumulation — the MXU contract and the
    # exact analogue of the cluster's expanding dot product.
    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("dtype", "block"))
def matmul_tp(x: jax.Array, y: jax.Array, *, dtype=jnp.float16, block=None):
    """Transprecision matmul: quantize f32 inputs to ``dtype`` (float16 or
    bfloat16), multiply in tiles with f32 accumulation, return f32.

    Shapes must be multiples of the block sizes (the near-sensor models in
    `model.py` pad accordingly).
    """
    bm, bn, bk = block or (BLOCK_M, BLOCK_N, BLOCK_K)
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, "inner dimensions must agree"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k2},{n}) must tile by {(bm, bn, bk)}"
    )
    xq = x.astype(dtype)
    yq = y.astype(dtype)
    n_k = k // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,  # CPU-PJRT executable; TPU would emit Mosaic.
    )(xq, yq)
    return out
