"""L1 Pallas kernel vs the pure-jnp oracle — the CORE build-time
correctness signal. Hypothesis sweeps tile-aligned shapes, dtypes and value
ranges; exact agreement is required (same quantize → f32-accumulate
contract on CPU interpret mode)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul_tp import matmul_tp
from compile.kernels.ref import matmul_tp_ref, quantize_roundtrip

jax.config.update("jax_platforms", "cpu")

BLOCK = (16, 16, 16)


def _rand(shape, lo, hi, seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16])
@pytest.mark.parametrize("mnk", [(16, 16, 16), (32, 16, 48), (64, 64, 64)])
def test_matmul_tp_matches_ref(dtype, mnk):
    m, n, k = mnk
    x = _rand((m, k), -2.0, 2.0, seed=m + n)
    y = _rand((k, n), -2.0, 2.0, seed=k)
    out = matmul_tp(jnp.asarray(x), jnp.asarray(y), dtype=dtype, block=BLOCK)
    ref = matmul_tp_ref(jnp.asarray(x), jnp.asarray(y), dtype=dtype)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    ki=st.integers(1, 4),
    scale=st.sampled_from([0.1, 1.0, 8.0]),
    dt=st.sampled_from(["f16", "bf16"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tp_hypothesis(mi, ni, ki, scale, dt, seed):
    dtype = jnp.float16 if dt == "f16" else jnp.bfloat16
    m, n, k = 16 * mi, 16 * ni, 16 * ki
    x = _rand((m, k), -scale, scale, seed)
    y = _rand((k, n), -scale, scale, seed ^ 0xABCD)
    out = matmul_tp(jnp.asarray(x), jnp.asarray(y), dtype=dtype, block=BLOCK)
    ref = matmul_tp_ref(jnp.asarray(x), jnp.asarray(y), dtype=dtype)
    # Tile-split accumulation reorders the f32 sums; bound the error by the
    # classic |Σ| ≤ k·scale² growth of partial-sum rounding.
    atol = 1e-5 + k * scale * scale * 2e-6
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=atol)


def test_quantization_is_rne():
    # The f32→f16 lattice must match IEEE RNE — same constants as the Rust
    # transfp tests (spec.rs).
    vals = np.array([0.1, 65504.0, 65520.0, 6.103515625e-05], np.float32)
    q = np.asarray(quantize_roundtrip(jnp.asarray(vals), jnp.float16))
    assert q[0] == np.float32(np.float16(0.1))
    assert q[1] == 65504.0
    assert np.isinf(q[2])  # rounds to inf
    assert q[3] == 6.103515625e-05


def test_accumulation_is_f32_not_f16():
    # 2048 ones: an f16 accumulator saturates at 2048 (ulp=2), f32 doesn't.
    k = 2048
    x = jnp.ones((16, k), jnp.float32)
    y = jnp.ones((k, 16), jnp.float32)
    out = matmul_tp(x, y, dtype=jnp.float16, block=(16, 16, 16))
    assert float(out[0, 0]) == float(k), "accumulation must be binary32"


def test_shape_validation():
    with pytest.raises(AssertionError):
        matmul_tp(jnp.ones((10, 16)), jnp.ones((16, 16)), block=BLOCK)
