"""L2 golden-model sanity: shapes, reference numerics vs plain numpy, and
the structural properties the Rust simulator relies on (layouts, constant
tables, bit-reversed FFT order)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model

jax.config.update("jax_platforms", "cpu")


def rnd(shape, seed, lo=-1.0, hi=1.0):
    return np.random.default_rng(seed).uniform(lo, hi, shape).astype(np.float32)


def test_matmul_f32_is_plain_dot():
    a, b = rnd((8, 8), 1), rnd((8, 8), 2)
    (c,) = model.matmul_f32(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(c), a @ b, rtol=1e-5, atol=1e-6)


def test_matmul_f16_quantizes_both_sides():
    a, b = rnd((16, 16), 3), rnd((16, 16), 4)
    (c,) = model.matmul_f16(jnp.asarray(a), jnp.asarray(b))
    ref = (a.astype(np.float16).astype(np.float32) @ b.astype(np.float16).astype(np.float32))
    ref = ref.astype(np.float16).astype(np.float32)
    np.testing.assert_allclose(np.asarray(c), ref, rtol=2e-3, atol=1e-3)


def test_fir_matches_numpy_correlate():
    x, h = rnd((64 + 16,), 5), rnd((16,), 6)
    (y,) = model.fir_f32(jnp.asarray(x), jnp.asarray(h))
    ref = np.correlate(x, h, mode="valid")[:64]
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-6)


def test_conv_is_correlation_not_convolution():
    img = rnd((8, 8), 7)
    k = np.zeros((3, 3), np.float32)
    k[0, 1] = 1.0  # picks img[oy+0, ox+1] — flipped if XLA convolved.
    (out,) = model.conv_f32(jnp.asarray(img), jnp.asarray(k))
    np.testing.assert_allclose(np.asarray(out).reshape(6, 6), img[0:6, 1:7], rtol=1e-6)


def test_dwt_layout_and_energy():
    x = rnd((64,), 8)
    (out,) = model.dwt_f32(jnp.asarray(x))
    assert out.shape == (64,)
    # Orthonormal db2 with zero-extension: energy preserved up to the edge
    # loss of the truncated support (always ≤ input energy).
    e_in, e_out = float(np.sum(x**2)), float(jnp.sum(out**2))
    assert e_out <= e_in + 1e-4
    assert e_out > 0.85 * e_in


def test_fft_bitrev_order():
    n = 16
    t = np.arange(n)
    re = np.cos(2 * np.pi * 3 * t / n).astype(np.float32)
    x = np.zeros(2 * n, np.float32)
    x[0::2] = re
    (out,) = model.fft_f32(jnp.asarray(x))
    y = np.asarray(out).reshape(n, 2)
    mags = np.hypot(y[:, 0], y[:, 1])
    # Bin 3 (and its mirror 13) carry the energy; bin 3 in bit-reversed
    # order (4 bits) sits at index reverse(0011) = 1100 = 12.
    assert mags[12] > 7.0, mags
    assert mags[0] < 1e-3


def test_iir_matches_scipy_style_recursion():
    x = rnd((32,), 9)
    (y,) = model.iir_f32(jnp.asarray(x))
    b, a = model.IIR_B, model.IIR_A
    ref = np.zeros(32, np.float32)
    y1 = y2 = 0.0
    for i in range(32):
        w = b[0] * x[i] + b[1] * (x[i - 1] if i >= 1 else 0) + b[2] * (x[i - 2] if i >= 2 else 0)
        v = w + a[0] * y1 + a[1] * y2
        ref[i] = v
        y2, y1 = y1, v
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-5)


def test_kmeans_update_with_empty_cluster():
    pts = np.array([[0.0, 0.0], [0.1, 0.0], [5.0, 5.0]], np.float32)
    cent = np.array([[0.0, 0.0], [5.0, 5.0], [100.0, 100.0]], np.float32)
    (newc,) = model.kmeans_f32(jnp.asarray(pts), jnp.asarray(cent))
    newc = np.asarray(newc).reshape(3, 2)
    np.testing.assert_allclose(newc[0], [0.05, 0.0], atol=1e-6)
    np.testing.assert_allclose(newc[1], [5.0, 5.0], atol=1e-6)
    np.testing.assert_allclose(newc[2], [100.0, 100.0], atol=1e-6)  # empty: kept


def test_svm_sign():
    sv = rnd((8, 4), 10)
    alpha = rnd((8,), 11)
    x = rnd((4,), 12)
    (out,) = model.svm_f32(*map(jnp.asarray, (sv, alpha, x, np.zeros(1, np.float32))))
    score = float(alpha @ (sv @ x))
    assert abs(float(out[0]) - score) < 1e-4
    assert float(out[1]) == (1.0 if score >= 0 else -1.0)


def test_exg_mlp_shapes_and_range():
    w = rnd((16, 64), 13)
    w1 = rnd((64, 64), 14, -0.2, 0.2)
    w2 = rnd((64, 16), 15, -0.2, 0.2)
    (logits,) = model.exg_mlp(*map(jnp.asarray, (w, w1, w2)))
    assert logits.shape == (16, 16)
    assert np.isfinite(np.asarray(logits)).all()


def test_constants_match_rust():
    # Guards against drift between model.py and rust/src/kernels/*.rs.
    np.testing.assert_allclose(model.DWT_H, [0.4829629, 0.8365163, 0.22414387, -0.12940952])
    np.testing.assert_allclose(model.IIR_B, [0.2929, 0.5858, 0.2929])
    np.testing.assert_allclose(model.IIR_A, [1.0, -0.34])
